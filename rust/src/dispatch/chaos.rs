//! Deterministic chaos harness: seeded fault injection behind the
//! [`WorkerTransport`] seam.
//!
//! The paper's claim is adversarial robustness, so the dispatcher's
//! test surface needs an *adversary*, not just the stochastic crash
//! knobs the transport used to carry. [`ChaosTransport`] wraps any
//! inner transport and injects faults decided by a [`FaultPlan`]:
//!
//! * **crash-class** — kill mid-range, hang forever, delayed start,
//!   truncated (unparseable) manifest: caught by the dispatcher's
//!   existing retry/reap machinery;
//! * **byzantine-class** — flipped value bits *with refolded stats*
//!   (structurally self-consistent, so only the result audit can catch
//!   it), wrong-range results, stale-manifest replays: caught by
//!   [`super::Dispatcher`]'s structural validation + re-execution
//!   audit.
//!
//! Every decision is drawn from a PRNG substream keyed only by
//! `(chaos_seed, lease range, attempt)` — never by wall clock or
//! generator position — so a replayed plan (same seed, same sweep)
//! makes **identical fault decisions** regardless of worker timing.
//! The per-range `attempt` counter makes retries of a killed range
//! redraw instead of dying forever. [`FaultPlan::log`] records the
//! decision sequence for replay assertions.
//!
//! The old ad-hoc one-shot knobs (`LocalProcess::inject_kill`, the
//! dispatcher's `fault_delay_ms`) are now thin presets over this
//! wrapper: [`ChaosTransport::preset_kill`] / [`ChaosTransport::preset_delay`].

use crate::error::{Error, Result};
use crate::obs::{Event, Obs};
use crate::prng;
use crate::sweep::shard::ShardResult;
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::queue::WorkerId;
use super::transport::{WorkerJob, WorkerPoll, WorkerTransport};

/// One injected fault, fully parameterized (so a logged plan replays
/// exactly).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// behave honestly
    None,
    /// kill the worker this long after the job starts (the result, even
    /// if the inner worker finished first, is discarded — the machine
    /// died mid-range)
    Kill { after_ms: u64 },
    /// never report completion; the dispatcher's lease deadline reaps
    Hang,
    /// slow the job's startup (straggler)
    Delay { ms: u64 },
    /// deliver a manifest truncated mid-write (fails to parse)
    Truncate,
    /// byzantine: flip one mantissa bit of one per-trial value and
    /// refold the stats block so the manifest stays self-consistent —
    /// invisible to structural validation, only the audit catches it
    FlipBit { pick: u64, bit: u32 },
    /// byzantine: return a manifest covering a shifted range
    WrongRange,
    /// byzantine: replay the previously delivered manifest
    StaleReplay,
}

impl Fault {
    fn describe(&self) -> String {
        match self {
            Fault::None => "honest".into(),
            Fault::Kill { after_ms } => format!("kill after {after_ms}ms"),
            Fault::Hang => "hang".into(),
            Fault::Delay { ms } => format!("delay {ms}ms"),
            Fault::Truncate => "truncate manifest".into(),
            Fault::FlipBit { pick, bit } => format!("flip bit {bit} of value #{pick}"),
            Fault::WrongRange => "wrong-range manifest".into(),
            Fault::StaleReplay => "stale-manifest replay".into(),
        }
    }
}

/// Per-fault-class probabilities (plus magnitudes) a [`FaultPlan`]
/// draws from. All probabilities are independent cut-points of one
/// uniform draw, so their sum must be <= 1.
#[derive(Clone, Debug, Default)]
pub struct ChaosProfile {
    pub kill: f64,
    pub hang: f64,
    pub delay: f64,
    pub truncate: f64,
    pub byzantine: f64,
    pub wrong_range: f64,
    pub stale: f64,
    /// upper bound (ms) for drawn delays and kill points
    pub delay_ms: u64,
    /// a pinned always-byzantine worker: every manifest it returns —
    /// lease or audit job — gets a consistent bit flip. This is the
    /// adversary the audit + quarantine pipeline must catch.
    pub byzantine_worker: Option<WorkerId>,
}

impl ChaosProfile {
    /// The all-zero profile (honest pass-through).
    pub fn none() -> Self {
        Self { delay_ms: 50, ..Self::default() }
    }

    /// Parse a profile spec: a preset name (`none`, `kills`, `flaky`,
    /// `byzantine`) or a comma-separated `key=value` list with keys
    /// `kill`, `hang`, `delay`, `truncate`, `byzantine`, `wrong-range`,
    /// `stale` (probabilities in [0,1]), `delay-ms` (u64) and
    /// `byz-worker` (worker id pinned always-byzantine).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut prof = Self::none();
        match spec.trim() {
            "" | "none" => return Ok(prof),
            "kills" => {
                prof.kill = 0.25;
                return Ok(prof);
            }
            "flaky" => {
                prof.kill = 0.15;
                prof.delay = 0.3;
                prof.truncate = 0.05;
                return Ok(prof);
            }
            "byzantine" => {
                prof.byzantine = 0.2;
                prof.wrong_range = 0.05;
                prof.stale = 0.05;
                return Ok(prof);
            }
            _ => {}
        }
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| Error::msg(format!("bad chaos profile entry '{part}' (want key=value)")))?;
            let fprob = || -> Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|e| Error::msg(format!("bad chaos profile value '{part}': {e}")))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(Error::msg(format!(
                        "chaos probability '{part}' outside [0, 1]"
                    )));
                }
                Ok(p)
            };
            match k.trim() {
                "kill" => prof.kill = fprob()?,
                "hang" => prof.hang = fprob()?,
                "delay" => prof.delay = fprob()?,
                "truncate" => prof.truncate = fprob()?,
                "byzantine" => prof.byzantine = fprob()?,
                "wrong-range" => prof.wrong_range = fprob()?,
                "stale" => prof.stale = fprob()?,
                "delay-ms" => {
                    prof.delay_ms = v
                        .parse()
                        .map_err(|e| Error::msg(format!("bad chaos profile value '{part}': {e}")))?
                }
                "byz-worker" => {
                    prof.byzantine_worker = Some(v.parse().map_err(|e| {
                        Error::msg(format!("bad chaos profile value '{part}': {e}"))
                    })?)
                }
                other => {
                    return Err(Error::msg(format!("unknown chaos profile key '{other}'")))
                }
            }
        }
        let total = prof.kill
            + prof.hang
            + prof.delay
            + prof.truncate
            + prof.byzantine
            + prof.wrong_range
            + prof.stale;
        if total > 1.0 + 1e-12 {
            return Err(Error::msg(format!(
                "chaos profile probabilities sum to {total:.3} > 1"
            )));
        }
        Ok(prof)
    }

    fn is_active(&self) -> bool {
        self.kill > 0.0
            || self.hang > 0.0
            || self.delay > 0.0
            || self.truncate > 0.0
            || self.byzantine > 0.0
            || self.wrong_range > 0.0
            || self.stale > 0.0
            || self.byzantine_worker.is_some()
    }
}

/// Seeded, replayable fault schedule. Decisions are keyed by
/// `(seed, range, attempt)`; per-worker one-shot presets (the old
/// `inject_kill`/`hang_worker` knobs) are consumed first.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    profile: ChaosProfile,
    /// one-shot faults per worker, consumed FIFO before any drawn fault
    one_shots: BTreeMap<WorkerId, VecDeque<Fault>>,
    /// per-range attempt counters (retries of a faulted range redraw)
    attempts: BTreeMap<(usize, usize), u64>,
    /// human-readable decision sequence, worker-independent for the
    /// drawn part — two runs with the same seed log the same decisions
    pub log: Vec<String>,
}

impl FaultPlan {
    pub fn new(seed: u64, profile: ChaosProfile) -> Self {
        Self { seed, profile, one_shots: BTreeMap::new(), attempts: BTreeMap::new(), log: Vec::new() }
    }

    /// Arm a one-shot fault on `worker`'s next not-yet-faulted job.
    pub fn push_one_shot(&mut self, worker: WorkerId, fault: Fault) {
        self.one_shots.entry(worker).or_default().push_back(fault);
    }

    /// Decide the fault (if any) for this job. Deterministic in
    /// `(seed, lo, hi, attempt)` — see the module docs.
    pub fn decide(&mut self, worker: WorkerId, lo: usize, hi: usize) -> Fault {
        if let Some(f) = self.one_shots.get_mut(&worker).and_then(VecDeque::pop_front) {
            self.log.push(format!(
                "one-shot worker {worker} lease [{lo}, {hi}): {}",
                f.describe()
            ));
            return f;
        }
        let attempt = {
            let a = self.attempts.entry((lo, hi)).or_insert(0);
            let cur = *a;
            *a += 1;
            cur
        };
        let mut rng = prng::substream(self.seed, chaos_key(lo, hi, attempt));
        // the pinned adversary corrupts everything it touches, lease or
        // audit job alike — drawn from the same keyed stream so the
        // flipped bit replays too
        if self.profile.byzantine_worker == Some(worker) {
            let f = Fault::FlipBit { pick: rng.next_u64(), bit: rng.below(52) as u32 };
            self.log.push(format!(
                "byz-worker lease [{lo}, {hi}) attempt {attempt}: {}",
                f.describe()
            ));
            return f;
        }
        let p = &self.profile;
        let span_ms = p.delay_ms.max(1) as usize;
        let u = rng.f64();
        let mut cut = 0.0;
        let mut pick = |prob: f64| {
            cut += prob;
            u < cut
        };
        let f = if pick(p.kill) {
            Fault::Kill { after_ms: rng.below(span_ms) as u64 }
        } else if pick(p.hang) {
            Fault::Hang
        } else if pick(p.delay) {
            Fault::Delay { ms: 1 + rng.below(span_ms) as u64 }
        } else if pick(p.truncate) {
            Fault::Truncate
        } else if pick(p.byzantine) {
            Fault::FlipBit { pick: rng.next_u64(), bit: rng.below(52) as u32 }
        } else if pick(p.wrong_range) {
            Fault::WrongRange
        } else if pick(p.stale) {
            Fault::StaleReplay
        } else {
            Fault::None
        };
        if f != Fault::None {
            self.log.push(format!(
                "lease [{lo}, {hi}) attempt {attempt}: {}",
                f.describe()
            ));
        }
        f
    }
}

/// Mix a lease's identity into one substream key. Plain multiply-xor
/// mixing — only has to decorrelate, not survive an adversary.
fn chaos_key(lo: usize, hi: usize, attempt: u64) -> u64 {
    (lo as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (hi as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        ^ attempt.wrapping_mul(0x1656_67B1_9E37_79F9)
}

/// What the wrapper is doing to a slot's current job.
#[derive(Debug)]
enum Armed {
    Honest,
    /// kill at this instant; inner completions before then are hidden
    Kill { at: Instant },
    Hang,
    Truncate,
    FlipBit { pick: u64, bit: u32 },
    WrongRange,
    StaleReplay,
}

/// A [`WorkerTransport`] wrapper injecting faults from a [`FaultPlan`].
/// Honest jobs pass straight through to the inner transport; faulted
/// jobs are sabotaged at the layer the fault class calls for (start,
/// poll or collect). See the module docs for the determinism contract.
pub struct ChaosTransport<T: WorkerTransport> {
    inner: T,
    pub plan: FaultPlan,
    slots: Vec<Armed>,
    /// most recent honestly delivered manifest (StaleReplay source)
    last_delivered: Option<ShardResult>,
    /// fault decisions stream out live as [`Event::ChaosFault`] (the
    /// plan's log stays the replay-assertion source of truth)
    obs: Obs,
}

impl<T: WorkerTransport> ChaosTransport<T> {
    pub fn new(inner: T, seed: u64, profile: ChaosProfile) -> Self {
        let slots = (0..inner.n_workers()).map(|_| Armed::Honest).collect();
        Self {
            inner,
            plan: FaultPlan::new(seed, profile),
            slots,
            last_delivered: None,
            obs: Obs::default(),
        }
    }

    /// Attach an observability handle: every fault decision the plan
    /// logs is also emitted as a structured event the moment it lands.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
    }

    /// Preset over the plan replacing `LocalProcess::inject_kill`: kill
    /// `worker`'s next job this long after it starts (one-shot).
    pub fn preset_kill(&mut self, worker: WorkerId, after: Duration) {
        self.plan.push_one_shot(worker, Fault::Kill { after_ms: after.as_millis() as u64 });
    }

    /// Preset replacing the dispatcher's old `fault_delay_ms` knob:
    /// delay `worker`'s next job by `ms` (one-shot). A delay past the
    /// lease deadline simulates a worker that never heartbeats.
    pub fn preset_delay(&mut self, worker: WorkerId, ms: u64) {
        self.plan.push_one_shot(worker, Fault::Delay { ms });
    }

    /// Arm any one-shot fault (scripted byzantine tests).
    pub fn preset(&mut self, worker: WorkerId, fault: Fault) {
        self.plan.push_one_shot(worker, fault);
    }

    /// Whether any fault can ever fire (used by the CLI to report).
    pub fn is_active(&self) -> bool {
        self.plan.profile.is_active() || !self.plan.one_shots.is_empty()
    }

    pub fn inner(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T: WorkerTransport> WorkerTransport for ChaosTransport<T> {
    fn n_workers(&self) -> usize {
        self.inner.n_workers()
    }

    fn start(&mut self, worker: WorkerId, job: &WorkerJob) -> Result<()> {
        let logged = self.plan.log.len();
        let fault = self.plan.decide(worker, job.lo, job.hi);
        for line in &self.plan.log[logged..] {
            self.obs.emit(Event::ChaosFault { detail: line.clone() });
        }
        match fault {
            Fault::None => {
                self.slots[worker] = Armed::Honest;
                self.inner.start(worker, job)
            }
            Fault::Delay { ms } => {
                // ride the transport's own startup-delay hook so a real
                // subprocess is genuinely slow, not just reported slow
                let mut slowed = job.clone();
                slowed.delay_ms += ms;
                self.slots[worker] = Armed::Honest;
                self.inner.start(worker, &slowed)
            }
            Fault::Kill { after_ms } => {
                self.slots[worker] =
                    Armed::Kill { at: Instant::now() + Duration::from_millis(after_ms) };
                self.inner.start(worker, job)
            }
            Fault::Hang => {
                self.slots[worker] = Armed::Hang;
                self.inner.start(worker, job)
            }
            Fault::Truncate => {
                self.slots[worker] = Armed::Truncate;
                self.inner.start(worker, job)
            }
            Fault::FlipBit { pick, bit } => {
                self.slots[worker] = Armed::FlipBit { pick, bit };
                self.inner.start(worker, job)
            }
            Fault::WrongRange => {
                self.slots[worker] = Armed::WrongRange;
                self.inner.start(worker, job)
            }
            Fault::StaleReplay => {
                self.slots[worker] = Armed::StaleReplay;
                self.inner.start(worker, job)
            }
        }
    }

    fn poll(&mut self, worker: WorkerId) -> WorkerPoll {
        match self.slots[worker] {
            Armed::Kill { at } => {
                if Instant::now() >= at {
                    self.inner.kill(worker);
                    self.slots[worker] = Armed::Honest;
                    return WorkerPoll::Failed(format!(
                        "worker {worker}: chaos killed the machine mid-range"
                    ));
                }
                // hide an early inner completion: the kill must land
                // mid-range, not race the worker
                match self.inner.poll(worker) {
                    WorkerPoll::Done | WorkerPoll::Running | WorkerPoll::Idle => {
                        WorkerPoll::Running
                    }
                    f @ WorkerPoll::Failed(_) => {
                        self.slots[worker] = Armed::Honest;
                        f
                    }
                }
            }
            // a hung machine answers nothing; the lease deadline reaps
            Armed::Hang => WorkerPoll::Running,
            _ => self.inner.poll(worker),
        }
    }

    fn kill(&mut self, worker: WorkerId) {
        self.slots[worker] = Armed::Honest;
        self.inner.kill(worker);
    }

    fn collect(&mut self, worker: WorkerId) -> Result<ShardResult> {
        let armed = std::mem::replace(&mut self.slots[worker], Armed::Honest);
        let res = self.inner.collect(worker)?;
        match armed {
            Armed::Honest | Armed::Kill { .. } | Armed::Hang => {
                self.last_delivered = Some(res.clone());
                Ok(res)
            }
            Armed::Truncate => {
                // corrupt the real manifest text and push it through the
                // real parser — proving the parse layer rejects it
                let text = res.render();
                let cut = &text[..text.len() * 2 / 3];
                ShardResult::parse(cut)
                    .map_err(|e| Error::msg(format!("chaos-truncated manifest: {e}")))
            }
            Armed::FlipBit { pick, bit } => {
                if res.stats_only || res.values.is_empty() {
                    // nothing to corrupt consistently; stay honest
                    return Ok(res);
                }
                let mut values = res.values.clone();
                let idx = (pick % values.len() as u64) as usize;
                values[idx] = f64::from_bits(values[idx].to_bits() ^ (1u64 << (bit % 52)));
                // refold the stats so the forgery is self-consistent:
                // structural validation passes, only the audit catches it
                Ok(ShardResult::from_values(res.config.clone(), res.lo, res.hi, values))
            }
            Armed::WrongRange => {
                let (lo, hi, trials) = (res.lo, res.hi, res.config.trials);
                let len = hi - lo;
                let (nlo, nhi) = if hi + len <= trials {
                    (lo + len, hi + len)
                } else if lo >= len {
                    (lo - len, hi - len)
                } else if len > 1 {
                    (lo, hi - 1)
                } else {
                    // 1-trial sweep: no wrong range exists, stay honest
                    return Ok(res);
                };
                let keep = (nhi - nlo).min(res.values.len());
                Ok(ShardResult::from_values(
                    res.config.clone(),
                    nlo,
                    nhi,
                    res.values[..keep].to_vec(),
                ))
            }
            Armed::StaleReplay => match self.last_delivered.clone() {
                Some(prev) => Ok(prev),
                None => Ok(res), // nothing banked to replay yet
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_profile() -> ChaosProfile {
        ChaosProfile {
            kill: 0.2,
            hang: 0.05,
            delay: 0.2,
            truncate: 0.1,
            byzantine: 0.1,
            wrong_range: 0.05,
            stale: 0.05,
            delay_ms: 40,
            byzantine_worker: None,
        }
    }

    #[test]
    fn fault_plan_replays_identically() {
        // acceptance contract: same seed, same (range, attempt)
        // sequence => identical fault decisions and identical log
        let ranges: Vec<(usize, usize)> = (0..20).map(|i| (i * 16, i * 16 + 16)).collect();
        let run = |seed: u64| {
            let mut plan = FaultPlan::new(seed, mixed_profile());
            let mut decisions = Vec::new();
            for &(lo, hi) in &ranges {
                // two attempts per range exercise the attempt counter
                decisions.push(plan.decide(0, lo, hi));
                decisions.push(plan.decide(1, lo, hi));
            }
            (decisions, plan.log)
        };
        let (d1, l1) = run(42);
        let (d2, l2) = run(42);
        assert_eq!(d1, d2, "same seed must replay the same fault sequence");
        assert_eq!(l1, l2);
        let (d3, _) = run(43);
        assert_ne!(d1, d3, "different seeds must differ somewhere");
        // decisions are worker-independent (drawn from the range key):
        // swapping which worker asks changes nothing
        let mut plan = FaultPlan::new(42, mixed_profile());
        let mut swapped = Vec::new();
        for &(lo, hi) in &ranges {
            swapped.push(plan.decide(7, lo, hi));
            swapped.push(plan.decide(3, lo, hi));
        }
        assert_eq!(d1, swapped);
    }

    #[test]
    fn attempts_redraw_and_mix() {
        // the same range redraws on retry (attempt keying) — with a
        // kill-heavy profile, some range must eventually draw honest
        let profile = ChaosProfile { kill: 0.5, delay_ms: 10, ..ChaosProfile::none() };
        let mut plan = FaultPlan::new(7, profile);
        let mut kinds = std::collections::BTreeSet::new();
        for _ in 0..32 {
            match plan.decide(0, 0, 16) {
                Fault::Kill { .. } => kinds.insert("kill"),
                Fault::None => kinds.insert("honest"),
                _ => unreachable!("profile only draws kills"),
            };
        }
        assert_eq!(kinds.len(), 2, "attempt counter never redrew: {kinds:?}");
    }

    #[test]
    fn one_shots_fire_first_then_fifo() {
        let mut plan = FaultPlan::new(0, ChaosProfile::none());
        plan.push_one_shot(1, Fault::Kill { after_ms: 5 });
        plan.push_one_shot(1, Fault::Delay { ms: 9 });
        assert_eq!(plan.decide(1, 0, 8), Fault::Kill { after_ms: 5 });
        assert_eq!(plan.decide(1, 8, 16), Fault::Delay { ms: 9 });
        assert_eq!(plan.decide(1, 16, 24), Fault::None);
        // other workers unaffected
        assert_eq!(plan.decide(0, 24, 32), Fault::None);
    }

    #[test]
    fn pinned_byzantine_worker_always_flips() {
        let profile =
            ChaosProfile { byzantine_worker: Some(2), ..ChaosProfile::none() };
        let mut plan = FaultPlan::new(11, profile);
        for i in 0..8 {
            match plan.decide(2, i * 8, i * 8 + 8) {
                Fault::FlipBit { .. } => {}
                f => panic!("pinned byzantine worker drew {f:?}"),
            }
            assert_eq!(plan.decide(0, i * 8, i * 8 + 8), Fault::None);
        }
    }

    #[test]
    fn profile_parser_presets_and_specs() {
        assert!(!ChaosProfile::parse("none").unwrap().is_active());
        assert!(ChaosProfile::parse("kills").unwrap().kill > 0.0);
        assert!(ChaosProfile::parse("flaky").unwrap().delay > 0.0);
        assert!(ChaosProfile::parse("byzantine").unwrap().byzantine > 0.0);
        let p = ChaosProfile::parse("kill=0.2,delay=0.3,delay-ms=80,byz-worker=1").unwrap();
        assert_eq!(p.kill, 0.2);
        assert_eq!(p.delay, 0.3);
        assert_eq!(p.delay_ms, 80);
        assert_eq!(p.byzantine_worker, Some(1));
        // rejections: bad key, bad value, probabilities over 1
        assert!(ChaosProfile::parse("explode=1").is_err());
        assert!(ChaosProfile::parse("kill=maybe").is_err());
        assert!(ChaosProfile::parse("kill=2").is_err());
        assert!(ChaosProfile::parse("kill=0.7,hang=0.7").is_err());
        assert!(ChaosProfile::parse("kill").is_err());
    }
}
