//! Dispatch checkpoint journal: crash/interrupt recovery for
//! `gcod sweep-launch`.
//!
//! The dispatcher's fault tolerance (re-lease, speculate, retry) covers
//! *worker* failures; this module covers **dispatcher** failures — an
//! interrupted or retry-exhausted launch. With a journal configured
//! ([`super::DispatchConfig::journal`]), every successfully collected
//! lease is persisted as it completes:
//!
//! * the shard manifest is written to the journal's sidecar directory
//!   `<journal>.d/` (the same versioned JSON `gcod sweep-shard`
//!   emits), and
//! * a `done lo hi <file>` line is appended to the journal file, under
//!   a header that fingerprints the sweep identity + manifest mode.
//!
//! `gcod sweep-launch --resume <journal>` replays the journal: entries
//! whose manifests still parse and match the sweep are pre-marked done
//! in the [`super::queue::WorkQueue`] (see [`WorkQueue::resume`]), so
//! the relaunch recomputes **only the uncovered ranges** — and because
//! per-trial values are split-invariant, the merged output is still
//! byte-identical to a single uninterrupted run. Unreadable or
//! mismatched entries are dropped (their ranges simply recompute);
//! resuming against a *different* sweep is a hard error. On a
//! successful merge the journal and its sidecar directory are removed.
//!
//! [`WorkQueue::resume`]: super::queue::WorkQueue::resume

use crate::bench_util::f64_to_hex_bits;
use crate::error::{Error, Result};
use crate::sweep::shard::{ShardResult, SweepConfig};
use std::io::Write;
use std::path::{Path, PathBuf};

/// First journal line; bumped if the entry format ever changes.
pub const JOURNAL_HEADER: &str = "gcod-sweep-journal v1";

/// One line identifying the sweep a journal belongs to. Compared for
/// whole-line equality on resume — a journal must never silently seed
/// a different sweep's merge.
pub fn fingerprint(cfg: &SweepConfig, stats_only: bool) -> String {
    format!(
        "{}|{}|{}|{}|{}|{}|{}|{:?}|{}",
        cfg.sweep.as_str(),
        cfg.scheme,
        cfg.decoder,
        f64_to_hex_bits(cfg.p),
        cfg.seed,
        cfg.trials,
        cfg.chunk,
        cfg.params,
        stats_only
    )
    .replace('\n', "\\n")
}

/// An open dispatch journal. See the module docs.
pub struct Journal {
    path: PathBuf,
    dir: PathBuf,
    file: std::fs::File,
    preloaded: Vec<ShardResult>,
    /// entries dropped during resume (stale/corrupt manifests) — the
    /// dispatcher surfaces these in its failure log
    pub notes: Vec<String>,
}

impl Journal {
    /// Sidecar manifest directory for a journal path.
    pub fn sidecar_dir(journal: &Path) -> PathBuf {
        PathBuf::from(format!("{}.d", journal.display()))
    }

    /// Open (and on `resume`, replay) the journal for one dispatch. The
    /// journal file is rewritten — atomically, via a temp file + rename
    /// — with the header plus the entries that survived validation, so
    /// it never references dropped manifests and a crash mid-open
    /// cannot lose banked entries. Guard rails: `resume` against a
    /// missing journal is a hard error (a typo'd path must not silently
    /// recompute everything), and a fresh open (`resume = false`)
    /// refuses to destroy an existing non-empty journal.
    pub fn open(
        path: &Path,
        cfg: &SweepConfig,
        stats_only: bool,
        resume: bool,
    ) -> Result<Journal> {
        if resume && !path.is_file() {
            return Err(Error::msg(format!(
                "resume journal {} not found — nothing to resume (start a checkpointed \
                 launch with --journal instead)",
                path.display()
            )));
        }
        if !resume
            && path.is_file()
            && std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false)
        {
            return Err(Error::msg(format!(
                "journal {} already exists — pass --resume to continue it, or remove it to \
                 start over (refusing to overwrite a checkpoint)",
                path.display()
            )));
        }
        let dir = Self::sidecar_dir(path);
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::msg(format!("create journal dir {}: {e}", dir.display())))?;
        let fp = fingerprint(cfg, stats_only);

        let mut preloaded: Vec<ShardResult> = Vec::new();
        let mut notes = Vec::new();
        if resume {
            let text = std::fs::read_to_string(path)
                .map_err(|e| Error::msg(format!("read journal {}: {e}", path.display())))?;
            let mut lines = text.lines();
            if lines.next() != Some(JOURNAL_HEADER) {
                return Err(Error::msg(format!(
                    "{} is not a {JOURNAL_HEADER} file",
                    path.display()
                )));
            }
            match lines.next() {
                Some(have) if have == fp => {}
                _ => {
                    return Err(Error::msg(format!(
                        "journal {} was written for a different sweep (identity fingerprint \
                         mismatch) — refusing to seed this dispatch with its results",
                        path.display()
                    )));
                }
            }
            for line in lines {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                match parse_entry(line, &dir, cfg, stats_only) {
                    Ok(res) => preloaded.push(res),
                    Err(e) => notes.push(format!("journal entry '{line}' dropped: {e}")),
                }
            }
        }

        // atomic rewrite: header + surviving entries land via rename, so
        // the old journal stays intact until the new one is complete
        let mut text = format!("{JOURNAL_HEADER}\n{fp}\n");
        for res in &preloaded {
            text.push_str(&format!("done {} {} {}\n", res.lo, res.hi, entry_file(res.lo, res.hi)));
        }
        let tmp = PathBuf::from(format!("{}.tmp", path.display()));
        std::fs::write(&tmp, &text)
            .map_err(|e| Error::msg(format!("write journal {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| Error::msg(format!("rename journal into {}: {e}", path.display())))?;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| Error::msg(format!("open journal {}: {e}", path.display())))?;
        Ok(Journal { path: path.to_path_buf(), dir, file, preloaded, notes })
    }

    /// Completed leases replayed from a prior run (drained by the
    /// dispatcher into its result set before the event loop starts).
    pub fn take_preloaded(&mut self) -> Vec<ShardResult> {
        std::mem::take(&mut self.preloaded)
    }

    /// Persist one freshly collected lease result. Duplicate covers of
    /// the same range (speculation) overwrite with identical bytes —
    /// per-trial values are split-invariant — and the duplicate line is
    /// deduplicated on resume by `dedup_cover`.
    pub fn record(&mut self, res: &ShardResult) -> Result<()> {
        res.write(&self.dir.join(entry_file(res.lo, res.hi)))?;
        self.append_line(res.lo, res.hi)
    }

    fn append_line(&mut self, lo: usize, hi: usize) -> Result<()> {
        writeln!(self.file, "done {lo} {hi} {}", entry_file(lo, hi))
            .and_then(|()| self.file.flush())
            .map_err(|e| Error::msg(format!("write journal {}: {e}", self.path.display())))
    }

    /// The dispatch merged successfully: the journal has served its
    /// purpose, remove it and its sidecar manifests.
    pub fn finish(self) {
        drop(self.file);
        let _ = std::fs::remove_file(&self.path);
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

fn entry_file(lo: usize, hi: usize) -> String {
    format!("done_{lo}_{hi}.json")
}

fn parse_entry(
    line: &str,
    dir: &Path,
    cfg: &SweepConfig,
    stats_only: bool,
) -> Result<ShardResult> {
    let mut parts = line.splitn(4, ' ');
    let (tag, lo, hi, file) = (parts.next(), parts.next(), parts.next(), parts.next());
    if tag != Some("done") {
        return Err(Error::msg("unknown journal entry tag"));
    }
    let lo: usize =
        lo.and_then(|s| s.parse().ok()).ok_or_else(|| Error::msg("bad journal entry lo"))?;
    let hi: usize =
        hi.and_then(|s| s.parse().ok()).ok_or_else(|| Error::msg("bad journal entry hi"))?;
    let file = file.ok_or_else(|| Error::msg("journal entry missing manifest file"))?;
    let res = ShardResult::read(&dir.join(file))?;
    if res.config != *cfg {
        return Err(Error::msg("manifest config differs from the dispatched sweep"));
    }
    if (res.lo, res.hi) != (lo, hi) {
        return Err(Error::msg(format!(
            "manifest covers [{}, {}), journal claims [{lo}, {hi})",
            res.lo, res.hi
        )));
    }
    if res.stats_only != stats_only {
        return Err(Error::msg("manifest stats-only mode differs from the dispatch"));
    }
    Ok(res)
}
