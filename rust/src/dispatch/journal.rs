//! Dispatch checkpoint journal: crash/interrupt recovery for
//! `gcod sweep-launch`.
//!
//! The dispatcher's fault tolerance (re-lease, speculate, retry) covers
//! *worker* failures; this module covers **dispatcher** failures — an
//! interrupted or retry-exhausted launch. With a journal configured
//! ([`super::DispatchConfig::journal`]), every successfully collected
//! lease is persisted as it completes:
//!
//! * the shard manifest is written to the journal's sidecar directory
//!   `<journal>.d/` (the same versioned JSON `gcod sweep-shard`
//!   emits) and fsynced, and only **then**
//! * a `done lo hi <file>` line is appended (and fsynced) to the
//!   journal file, under a header that fingerprints the sweep identity
//!   + manifest mode. A crash between the two leaves an unreferenced
//!   manifest (harmless) — never a journal line pointing at a hole.
//!
//! When the result audit condemns a worker, the ranges it banked are
//! retracted with `undo lo hi` entries: on resume an `undo` drops the
//! matching `done` entries that precede it (a later honest
//! re-completion appends a fresh `done` line, which stands). A torn
//! final line (append interrupted mid-write) is dropped with a note,
//! not a parse error.
//!
//! `gcod sweep-launch --resume <journal>` replays the journal: entries
//! whose manifests still parse and match the sweep are pre-marked done
//! in the [`super::queue::WorkQueue`] (see [`WorkQueue::resume`]), so
//! the relaunch recomputes **only the uncovered ranges** — and because
//! per-trial values are split-invariant, the merged output is still
//! byte-identical to a single uninterrupted run. Unreadable or
//! mismatched entries are dropped (their ranges simply recompute);
//! resuming against a *different* sweep is a hard error. On a
//! successful merge the journal and its sidecar directory are removed.
//!
//! [`WorkQueue::resume`]: super::queue::WorkQueue::resume

use crate::bench_util::f64_to_hex_bits;
use crate::error::{Error, Result};
use crate::sweep::shard::{ShardResult, SweepConfig};
use std::io::Write;
use std::path::{Path, PathBuf};

/// First journal line; bumped if the entry format ever changes.
pub const JOURNAL_HEADER: &str = "gcod-sweep-journal v1";

/// One line identifying the sweep a journal belongs to. Compared for
/// whole-line equality on resume — a journal must never silently seed
/// a different sweep's merge.
pub fn fingerprint(cfg: &SweepConfig, stats_only: bool) -> String {
    format!(
        "{}|{}|{}|{}|{}|{}|{}|{:?}|{}",
        cfg.sweep.as_str(),
        cfg.scheme,
        cfg.decoder,
        f64_to_hex_bits(cfg.p),
        cfg.seed,
        cfg.trials,
        cfg.chunk,
        cfg.params,
        stats_only
    )
    .replace('\n', "\\n")
}

/// An open dispatch journal. See the module docs.
pub struct Journal {
    path: PathBuf,
    dir: PathBuf,
    file: std::fs::File,
    preloaded: Vec<ShardResult>,
    /// entries dropped during resume (stale/corrupt manifests) — the
    /// dispatcher surfaces these in its failure log
    pub notes: Vec<String>,
}

impl Journal {
    /// Sidecar manifest directory for a journal path.
    pub fn sidecar_dir(journal: &Path) -> PathBuf {
        PathBuf::from(format!("{}.d", journal.display()))
    }

    /// Open (and on `resume`, replay) the journal for one dispatch. The
    /// journal file is rewritten — atomically, via a temp file + rename
    /// — with the header plus the entries that survived validation, so
    /// it never references dropped manifests and a crash mid-open
    /// cannot lose banked entries. Guard rails: `resume` against a
    /// missing journal is a hard error (a typo'd path must not silently
    /// recompute everything), and a fresh open (`resume = false`)
    /// refuses to destroy an existing non-empty journal.
    pub fn open(
        path: &Path,
        cfg: &SweepConfig,
        stats_only: bool,
        resume: bool,
    ) -> Result<Journal> {
        if resume && !path.is_file() {
            return Err(Error::msg(format!(
                "resume journal {} not found — nothing to resume (start a checkpointed \
                 launch with --journal instead)",
                path.display()
            )));
        }
        if !resume
            && path.is_file()
            && std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false)
        {
            return Err(Error::msg(format!(
                "journal {} already exists — pass --resume to continue it, or remove it to \
                 start over (refusing to overwrite a checkpoint)",
                path.display()
            )));
        }
        let dir = Self::sidecar_dir(path);
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::msg(format!("create journal dir {}: {e}", dir.display())))?;
        let fp = fingerprint(cfg, stats_only);

        let mut preloaded: Vec<ShardResult> = Vec::new();
        let mut notes = Vec::new();
        if resume {
            let text = std::fs::read_to_string(path)
                .map_err(|e| Error::msg(format!("read journal {}: {e}", path.display())))?;
            // every healthy append ends with a newline; a missing one
            // means the final line was torn mid-write — drop it with a
            // note instead of failing the whole resume on garbage
            let mut raw: Vec<&str> = text.lines().collect();
            if !text.is_empty() && !text.ends_with('\n') {
                if let Some(torn) = raw.pop() {
                    notes.push(format!(
                        "torn journal tail '{torn}' dropped (append interrupted mid-write)"
                    ));
                }
            }
            let mut lines = raw.into_iter();
            if lines.next() != Some(JOURNAL_HEADER) {
                return Err(Error::msg(format!(
                    "{} is not a {JOURNAL_HEADER} file",
                    path.display()
                )));
            }
            match lines.next() {
                Some(have) if have == fp => {}
                _ => {
                    return Err(Error::msg(format!(
                        "journal {} was written for a different sweep (identity fingerprint \
                         mismatch) — refusing to seed this dispatch with its results",
                        path.display()
                    )));
                }
            }
            // pass 1: tokenize, letting each `undo` retract the
            // `done` entries (exact bounds) that precede it
            let mut kept: Vec<(usize, usize, String)> = Vec::new();
            for line in lines {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                match parse_line(line) {
                    Ok(Entry::Done { lo, hi, file }) => kept.push((lo, hi, file.to_string())),
                    Ok(Entry::Undo { lo, hi }) => {
                        let before = kept.len();
                        kept.retain(|&(a, b, _)| (a, b) != (lo, hi));
                        if kept.len() == before {
                            notes.push(format!(
                                "journal undo [{lo}, {hi}) matched no banked entry"
                            ));
                        }
                    }
                    Err(e) => notes.push(format!("journal entry '{line}' dropped: {e}")),
                }
            }
            // pass 2: load + validate the surviving manifests
            for (lo, hi, file) in kept {
                match load_entry(lo, hi, &file, &dir, cfg, stats_only) {
                    Ok(res) => preloaded.push(res),
                    Err(e) => {
                        notes.push(format!("journal entry 'done {lo} {hi} {file}' dropped: {e}"));
                    }
                }
            }
        }

        // atomic rewrite: header + surviving entries land via rename, so
        // the old journal stays intact until the new one is complete
        let mut text = format!("{JOURNAL_HEADER}\n{fp}\n");
        for res in &preloaded {
            text.push_str(&format!("done {} {} {}\n", res.lo, res.hi, entry_file(res.lo, res.hi)));
        }
        let tmp = PathBuf::from(format!("{}.tmp", path.display()));
        std::fs::write(&tmp, &text)
            .map_err(|e| Error::msg(format!("write journal {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| Error::msg(format!("rename journal into {}: {e}", path.display())))?;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| Error::msg(format!("open journal {}: {e}", path.display())))?;
        Ok(Journal { path: path.to_path_buf(), dir, file, preloaded, notes })
    }

    /// Completed leases replayed from a prior run (drained by the
    /// dispatcher into its result set before the event loop starts).
    pub fn take_preloaded(&mut self) -> Vec<ShardResult> {
        std::mem::take(&mut self.preloaded)
    }

    /// Persist one freshly collected lease result. Duplicate covers of
    /// the same range (speculation) overwrite with identical bytes —
    /// per-trial values are split-invariant — and the duplicate line is
    /// deduplicated on resume by `dedup_cover`. Durability order:
    /// sidecar bytes are fsynced *before* the journal line that
    /// references them is appended and fsynced.
    pub fn record(&mut self, res: &ShardResult) -> Result<()> {
        let sidecar = self.dir.join(entry_file(res.lo, res.hi));
        std::fs::File::create(&sidecar)
            .and_then(|mut f| f.write_all(res.render().as_bytes()).and_then(|()| f.sync_all()))
            .map_err(|e| Error::msg(format!("write manifest {}: {e}", sidecar.display())))?;
        self.append_line(&format!("done {} {} {}", res.lo, res.hi, entry_file(res.lo, res.hi)))
    }

    /// The result audit condemned the worker that banked `[lo, hi)`:
    /// retract the entry so an interrupted launch cannot resume from a
    /// forged manifest. The sidecar removal is best-effort — the
    /// `undo` line alone already excludes the entry on resume.
    pub fn invalidate(&mut self, lo: usize, hi: usize) -> Result<()> {
        self.append_line(&format!("undo {lo} {hi}"))?;
        let _ = std::fs::remove_file(self.dir.join(entry_file(lo, hi)));
        Ok(())
    }

    fn append_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.file, "{line}")
            .and_then(|()| self.file.sync_data())
            .map_err(|e| Error::msg(format!("write journal {}: {e}", self.path.display())))
    }

    /// The dispatch merged successfully: the journal has served its
    /// purpose, remove it and its sidecar manifests.
    pub fn finish(self) {
        drop(self.file);
        let _ = std::fs::remove_file(&self.path);
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

fn entry_file(lo: usize, hi: usize) -> String {
    format!("done_{lo}_{hi}.json")
}

/// One tokenized journal line.
enum Entry<'a> {
    Done { lo: usize, hi: usize, file: &'a str },
    Undo { lo: usize, hi: usize },
}

fn parse_line(line: &str) -> Result<Entry<'_>> {
    let mut parts = line.splitn(4, ' ');
    let (tag, lo, hi, file) = (parts.next(), parts.next(), parts.next(), parts.next());
    let lo: usize =
        lo.and_then(|s| s.parse().ok()).ok_or_else(|| Error::msg("bad journal entry lo"))?;
    let hi: usize =
        hi.and_then(|s| s.parse().ok()).ok_or_else(|| Error::msg("bad journal entry hi"))?;
    match tag {
        Some("done") => {
            let file = file.ok_or_else(|| Error::msg("journal entry missing manifest file"))?;
            Ok(Entry::Done { lo, hi, file })
        }
        Some("undo") if file.is_none() => Ok(Entry::Undo { lo, hi }),
        _ => Err(Error::msg("unknown journal entry tag")),
    }
}

fn load_entry(
    lo: usize,
    hi: usize,
    file: &str,
    dir: &Path,
    cfg: &SweepConfig,
    stats_only: bool,
) -> Result<ShardResult> {
    let res = ShardResult::read(&dir.join(file))?;
    if res.config != *cfg {
        return Err(Error::msg("manifest config differs from the dispatched sweep"));
    }
    if (res.lo, res.hi) != (lo, hi) {
        return Err(Error::msg(format!(
            "manifest covers [{}, {}), journal claims [{lo}, {hi})",
            res.lo, res.hi
        )));
    }
    if res.stats_only != stats_only {
        return Err(Error::msg("manifest stats-only mode differs from the dispatch"));
    }
    Ok(res)
}
