//! Decoders: from a straggler pattern to coefficients (w, alpha).
//!
//! * [`OptimalGraphDecoder`] — the paper's linear-time optimal decoder
//!   for graph schemes (Section III): connected components of the
//!   surviving subgraph determine alpha*, and a spanning-tree
//!   back-substitution (plus one odd-cycle edge for non-bipartite
//!   components) produces a w* with A w* = alpha*. O(n + m) per decode,
//!   "the same order as computing the update itself".
//! * [`GenericOptimalDecoder`] — LSQR on the surviving columns,
//!   w* = argmin |A_S w - 1|_2 (Eq. 3) for arbitrary assignments.
//! * [`FixedDecoder`] — w_j = 1/(d (1-p)) on survivors (unbiased fixed
//!   coefficients, Section VIII).
//! * [`FrcOptimalDecoder`] — closed form for FRC group structure.
//! * [`IgnoreStragglersDecoder`] — the uncoded baseline.
//!
//! ## Batched decoding ( §Perf)
//!
//! The hot entry point is [`Decoder::decode_into`], which writes into a
//! caller-owned [`Decoding`] and allocates nothing after the first call:
//! every decoder keeps its working set in interior-mutable scratch, and
//! the Monte-Carlo [`crate::sweep::TrialEngine`] gives each worker its
//! own decoder instance so trials never contend. [`Decoder::decode`] is
//! a thin allocate-and-forward wrapper kept for one-shot callers.
//! [`GenericOptimalDecoder`] additionally warm-starts LSQR from the
//! previous trial's `w` (falling back to a cold start when the mask
//! changed on more than [`GenericOptimalDecoder::restart_fraction`] of
//! the machines), so consecutive similar patterns converge in a few
//! Golub-Kahan steps instead of O(m) of them.

use crate::codes::{FrcCode, GradientCode};
use crate::graphs::Graph;
use crate::linalg::LinalgBackend;
use crate::sparse::{lsqr_into_backend, Csc, Csr, DiagScaledMaskedOp, LsqrScratch, MaskedColumnsOp};

/// A decoded coefficient pair: per-machine weights w (zero on
/// stragglers) and the induced per-block alpha = A w.
#[derive(Clone, Debug)]
pub struct Decoding {
    pub w: Vec<f64>,
    pub alpha: Vec<f64>,
}

impl Decoding {
    /// An empty output buffer for [`Decoder::decode_into`]; sized (and
    /// thereafter reused without reallocating) by the first decode.
    pub fn empty() -> Self {
        Self { w: Vec::new(), alpha: Vec::new() }
    }

    /// Resize to (m machines, n blocks) and zero-fill. Keeps capacity,
    /// so repeated resets on the same scheme never reallocate.
    pub fn reset(&mut self, m: usize, n: usize) {
        self.w.clear();
        self.w.resize(m, 0.0);
        self.alpha.clear();
        self.alpha.resize(n, 0.0);
    }

    /// The paper's decoding error |alpha - 1|_2^2.
    pub fn error_sq(&self) -> f64 {
        crate::linalg::dist_to_ones_sq(&self.alpha)
    }
}

/// `straggler[j] == true` means machine j's result never arrived.
pub trait Decoder {
    /// Allocation-free decode into a caller-owned buffer (the batched
    /// hot path). `out` is fully overwritten; stale contents are fine.
    fn decode_into(&self, straggler: &[bool], out: &mut Decoding);

    /// Allocating convenience wrapper around [`Decoder::decode_into`].
    fn decode(&self, straggler: &[bool]) -> Decoding {
        let mut out = Decoding::empty();
        self.decode_into(straggler, &mut out);
        out
    }

    /// Inner-solve iteration count of the most recent decode, for
    /// decoders that are iterative under the hood (the generic LSQR
    /// decoder). `None` for closed-form decoders. Observability only —
    /// feeds the `lsqr_iterations_total` metric, never the decode.
    fn lsqr_iterations(&self) -> Option<u64> {
        None
    }

    fn name(&self) -> String;
}

impl<D: Decoder + ?Sized> Decoder for Box<D> {
    fn decode_into(&self, straggler: &[bool], out: &mut Decoding) {
        (**self).decode_into(straggler, out)
    }
    fn decode(&self, straggler: &[bool]) -> Decoding {
        (**self).decode(straggler)
    }
    fn lsqr_iterations(&self) -> Option<u64> {
        (**self).lsqr_iterations()
    }
    fn name(&self) -> String {
        (**self).name()
    }
}

// ---------------------------------------------------------------------
// Optimal graph decoder (Section III)
// ---------------------------------------------------------------------

pub struct OptimalGraphDecoder<'a> {
    pub g: &'a Graph,
    /// reusable scratch so repeated decodes are allocation-free on the
    /// hot path (the paper's "c*m operations" claim — §Perf)
    scratch: std::cell::RefCell<Scratch>,
}

#[derive(Default)]
struct Scratch {
    /// BFS discovery order; doubles as the queue
    order: Vec<usize>,
    /// usize::MAX = unvisited; otherwise component id
    comp_of: Vec<usize>,
    color: Vec<u8>,
    parent_edge: Vec<usize>,
    incident: Vec<f64>,
}

impl<'a> OptimalGraphDecoder<'a> {
    pub fn new(g: &'a Graph) -> Self {
        Self { g, scratch: std::cell::RefCell::new(Scratch::default()) }
    }
}

impl Decoder for OptimalGraphDecoder<'_> {
    fn name(&self) -> String {
        "optimal-graph".to_string()
    }

    /// Single-pass linear-time decode (Section III): one BFS splits the
    /// surviving subgraph into components and 2-colors them; alpha* is
    /// set per component (1/1 if an odd cycle exists, side-imbalance
    /// values if bipartite, 0 if isolated); w* follows by leaf-up
    /// spanning-tree substitution, with one odd non-tree edge carrying
    /// the color imbalance in non-bipartite components.
    fn decode_into(&self, straggler: &[bool], out: &mut Decoding) {
        let g = self.g;
        let (n, m) = (g.n, g.m());
        assert_eq!(straggler.len(), m);
        out.reset(m, n);
        let mut s = self.scratch.borrow_mut();
        s.order.clear();
        s.comp_of.clear();
        s.comp_of.resize(n, usize::MAX);
        s.color.resize(n, 0);
        s.parent_edge.resize(n, usize::MAX);
        s.incident.resize(n, 0.0);
        let Scratch { order, comp_of, color, parent_edge, incident } = &mut *s;

        let w = &mut out.w;
        let alpha = &mut out.alpha;

        for root in 0..n {
            if comp_of[root] != usize::MAX {
                continue;
            }
            let start = order.len();
            let cid = root; // any unique id per component
            comp_of[root] = cid;
            color[root] = 0;
            parent_edge[root] = usize::MAX;
            incident[root] = 0.0;
            order.push(root);
            // BFS; track 2-coloring, side counts, and one odd edge
            let (mut c0, mut c1) = (1usize, 0usize);
            let mut odd_edge = usize::MAX;
            let mut head = start;
            while head < order.len() {
                let u = order[head];
                head += 1;
                for &(v, eid) in &g.adj[u] {
                    if straggler[eid] {
                        continue;
                    }
                    if comp_of[v] == usize::MAX {
                        comp_of[v] = cid;
                        color[v] = 1 - color[u];
                        parent_edge[v] = eid;
                        incident[v] = 0.0;
                        if color[v] == 0 {
                            c0 += 1;
                        } else {
                            c1 += 1;
                        }
                        order.push(v);
                    } else if color[v] == color[u] && odd_edge == usize::MAX {
                        odd_edge = eid; // an odd (non-tree) edge
                    }
                }
            }
            let size = order.len() - start;
            if size == 1 {
                // isolated block: alpha stays 0, no weights
                continue;
            }
            // per-component alpha values (Section III obs. 1-3)
            let (a0, a1) = if odd_edge != usize::MAX {
                (1.0, 1.0)
            } else {
                let tot = (c0 + c1) as f64;
                (2.0 * c1 as f64 / tot, 2.0 * c0 as f64 / tot)
            };
            for &v in &order[start..] {
                alpha[v] = if color[v] == 0 { a0 } else { a1 };
            }
            if odd_edge != usize::MAX {
                // imbalance of targets across colors: alpha = 1 here, so
                // it is simply c0 - c1
                let imbalance = c0 as f64 - c1 as f64;
                let (u, v) = g.edges[odd_edge];
                let t = if color[u] == 0 { imbalance / 2.0 } else { -imbalance / 2.0 };
                w[odd_edge] = t;
                incident[u] += t;
                incident[v] += t;
            }
            // leaf-up substitution: each non-root vertex fixes its
            // parent edge so its incident sum reaches alpha[v]
            for idx in (start + 1..start + size).rev() {
                let v = order[idx];
                let e = parent_edge[v];
                let (x, y) = g.edges[e];
                let parent = if x == v { y } else { x };
                let we = alpha[v] - incident[v];
                w[e] += we;
                incident[v] += we;
                incident[parent] += we;
            }
            debug_assert!(
                (incident[order[start]] - alpha[order[start]]).abs() < 1e-6,
                "root constraint violated"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Generic optimal decoder (Eq. 3 via LSQR)
// ---------------------------------------------------------------------

/// Default [`GenericOptimalDecoder::restart_fraction`]: restart LSQR
/// cold when more than this fraction of machines flipped straggler
/// state since the previous decode. Exposed as a named tunable so the
/// `bench_decode_perf` restart-fraction sweep can set it from
/// measurements (a Bernoulli(p) mask pair flips ~2p(1-p) of the
/// machines in expectation, so 0.25 keeps warm starts active through
/// roughly p <= 0.15 of independent masks and any stagnant model).
/// Provisionally settled at that analytical value: no build container
/// has shipped a toolchain to run the sweep yet, and the knob is
/// bit-neutral (warm starts change iteration counts, not solutions
/// beyond atol), so re-tuning later costs nothing.
pub const DEFAULT_RESTART_FRACTION: f64 = 0.25;

pub struct GenericOptimalDecoder<'a> {
    pub a: &'a Csc,
    pub atol: f64,
    pub max_iter: usize,
    /// Warm-start guard: if more than this fraction of machines flipped
    /// straggler state since the previous decode, restart LSQR cold
    /// (the previous w is then a poor and potentially misleading guess).
    /// Defaults to [`DEFAULT_RESTART_FRACTION`]; negative forces every
    /// decode cold, >= 1.0 always warm-starts.
    pub restart_fraction: f64,
    /// Degree-diagonal (column-equilibration) preconditioning: LSQR
    /// runs on `A_S D` with `D = diag(1/|a_j|_2)` and the solution is
    /// un-scaled afterwards (`w = D z`). Off by default, and settled
    /// off until measured: the preconditioned iteration rounds
    /// differently, so a default flip is byte-affecting (SHARD_SCHEMA
    /// bump + golden re-bless) and only justified once
    /// `bench_decode_perf`'s preconditioning arm shows an
    /// iteration-count win on heterogeneous-degree codes. Turn on
    /// per-sweep via the `precond` param meanwhile.
    pub precond: bool,
    /// Which [`LinalgBackend`] tier the LSQR dense norms run on.
    /// `Exact` (the default) is byte-identical to the pre-backend
    /// decoder; `Fast` changes solution bits within the fast tier's
    /// documented tolerance but stays deterministic per input. Set
    /// per-sweep via the `linalg` param.
    pub backend: LinalgBackend,
    scratch: std::cell::RefCell<GenericScratch>,
}

#[derive(Default)]
struct GenericScratch {
    /// row-major mirror of `a`, built on first decode
    csr: Option<Csr>,
    /// all-ones RHS, kept resized to n
    rhs: Vec<f64>,
    /// previous trial's mask + solution for warm starting
    prev_mask: Vec<bool>,
    prev_w: Vec<f64>,
    has_prev: bool,
    /// per-column right preconditioner 1/|a_j|_2 (0 for empty columns);
    /// built on first preconditioned decode, empty otherwise
    col_scale: Vec<f64>,
    /// Golub-Kahan steps of the most recent decode (perf telemetry)
    last_iters: usize,
    lsqr: LsqrScratch,
}

impl<'a> GenericOptimalDecoder<'a> {
    pub fn new(a: &'a Csc) -> Self {
        Self {
            a,
            atol: 1e-12,
            max_iter: 4 * (a.rows + a.cols),
            restart_fraction: DEFAULT_RESTART_FRACTION,
            precond: false,
            backend: LinalgBackend::Exact,
            scratch: std::cell::RefCell::new(GenericScratch::default()),
        }
    }

    /// Builder-style override of the warm-start restart guard (the
    /// `bench_decode_perf` tuning sweep's knob).
    pub fn with_restart_fraction(mut self, fraction: f64) -> Self {
        self.restart_fraction = fraction;
        self
    }

    /// Builder-style toggle for degree-diagonal preconditioning (see
    /// the `precond` field).
    pub fn with_precond(mut self, on: bool) -> Self {
        self.precond = on;
        self
    }

    /// Builder-style selection of the linalg tier (see the `backend`
    /// field). `Exact` keeps the historical bits.
    pub fn with_backend(mut self, backend: LinalgBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Golub-Kahan iterations spent by the most recent
    /// [`Decoder::decode_into`] call (0 before any decode, or when the
    /// last mask had no survivors). Perf telemetry for the
    /// preconditioning comparison in `bench_decode_perf`.
    pub fn last_lsqr_iterations(&self) -> usize {
        self.scratch.borrow().last_iters
    }
}

impl Decoder for GenericOptimalDecoder<'_> {
    fn name(&self) -> String {
        "optimal-lsqr".to_string()
    }

    fn lsqr_iterations(&self) -> Option<u64> {
        Some(self.last_lsqr_iterations() as u64)
    }

    fn decode_into(&self, straggler: &[bool], out: &mut Decoding) {
        let (n, m) = (self.a.rows, self.a.cols);
        assert_eq!(straggler.len(), m);
        out.reset(m, n);
        let mut s = self.scratch.borrow_mut();
        if s.csr.is_none() {
            s.csr = Some(self.a.to_csr());
        }
        if self.precond && s.col_scale.is_empty() {
            // 1/|a_j|_2 per column, built once (pure function of A)
            s.col_scale = (0..m)
                .map(|j| {
                    let n2: f64 = self.a.col(j).1.iter().map(|v| v * v).sum();
                    if n2 > 0.0 { 1.0 / n2.sqrt() } else { 0.0 }
                })
                .collect();
        }
        if straggler.iter().all(|&d| d) {
            // no survivors: w = 0, alpha = 0, and nothing to warm-start
            // the next trial from
            s.has_prev = false;
            s.last_iters = 0;
            return;
        }
        let GenericScratch {
            csr,
            rhs,
            prev_mask,
            prev_w,
            has_prev,
            col_scale,
            last_iters,
            lsqr: ls,
        } = &mut *s;

        // warm start from the previous trial's w when the mask is close
        // enough; newly-dead columns are zeroed (LSQR keeps them at
        // exactly 0.0 through the masked op's apply_t). Preconditioned
        // solves run in z-space (w = D z), so the warm guess converts.
        let warm = *has_prev && prev_mask.len() == m && {
            let flips = prev_mask.iter().zip(straggler).filter(|(a, b)| a != b).count();
            flips as f64 <= self.restart_fraction * m as f64
        };
        if warm {
            for j in 0..m {
                if !straggler[j] {
                    out.w[j] = if self.precond {
                        let d = col_scale[j];
                        if d > 0.0 { prev_w[j] / d } else { 0.0 }
                    } else {
                        prev_w[j]
                    };
                }
            }
        }

        rhs.clear();
        rhs.resize(n, 1.0);
        let masked = MaskedColumnsOp {
            csc: self.a,
            csr: csr.as_ref().expect("csr built above"),
            straggler,
        };
        let summary = if self.precond {
            let op = DiagScaledMaskedOp { inner: masked, scale: col_scale };
            lsqr_into_backend(&op, rhs, self.atol, self.max_iter, &mut out.w, ls, self.backend)
        } else {
            lsqr_into_backend(&masked, rhs, self.atol, self.max_iter, &mut out.w, ls, self.backend)
        };
        *last_iters = summary.iterations;
        if self.precond {
            // back to w-space: w = D z (stragglers stay exactly 0.0)
            for (wj, &dj) in out.w.iter_mut().zip(col_scale.iter()) {
                *wj *= dj;
            }
        }
        self.a.mul_vec_into(&out.w, &mut out.alpha);

        prev_mask.clear();
        prev_mask.extend_from_slice(straggler);
        prev_w.clear();
        prev_w.extend_from_slice(&out.w);
        *has_prev = true;
    }
}

// ---------------------------------------------------------------------
// Fixed-coefficient decoder (unbiased): w_j = 1 / (d (1 - p))
// ---------------------------------------------------------------------

pub struct FixedDecoder<'a> {
    pub a: &'a Csc,
    /// replication factor d used in the normalization
    pub d: f64,
    /// straggler probability the coefficients are calibrated for
    pub p: f64,
}

impl<'a> FixedDecoder<'a> {
    pub fn new(a: &'a Csc, p: f64) -> Self {
        Self { a, d: a.replication_factor(), p }
    }
}

impl Decoder for FixedDecoder<'_> {
    fn name(&self) -> String {
        "fixed".to_string()
    }

    fn decode_into(&self, straggler: &[bool], out: &mut Decoding) {
        assert_eq!(straggler.len(), self.a.cols);
        out.reset(self.a.cols, self.a.rows);
        let coeff = 1.0 / (self.d * (1.0 - self.p));
        for (j, &s) in straggler.iter().enumerate() {
            if !s {
                out.w[j] = coeff;
            }
        }
        self.a.mul_vec_into(&out.w, &mut out.alpha);
    }
}

// ---------------------------------------------------------------------
// FRC closed-form optimal decoder
// ---------------------------------------------------------------------

pub struct FrcOptimalDecoder<'a> {
    pub code: &'a FrcCode,
    /// per-group survivor counts, reused across decodes
    scratch: std::cell::RefCell<Vec<usize>>,
}

impl<'a> FrcOptimalDecoder<'a> {
    pub fn new(code: &'a FrcCode) -> Self {
        Self { code, scratch: std::cell::RefCell::new(Vec::new()) }
    }
}

impl Decoder for FrcOptimalDecoder<'_> {
    fn name(&self) -> String {
        "optimal-frc".to_string()
    }

    /// Closed form (same math as [`FrcCode::optimal_decode`], without
    /// the per-call survivor-list allocations): every group with k >= 1
    /// surviving machines puts weight 1/k on each survivor (alpha = 1 on
    /// its blocks); dead groups contribute alpha = 0.
    fn decode_into(&self, straggler: &[bool], out: &mut Decoding) {
        let a = self.code.assignment();
        let m = a.cols;
        assert_eq!(straggler.len(), m);
        out.reset(m, a.rows);
        let groups = self.code.n_groups();
        let mut cnt = self.scratch.borrow_mut();
        cnt.clear();
        cnt.resize(groups, 0);
        for j in 0..m {
            if !straggler[j] {
                cnt[self.code.machine_group[j]] += 1;
            }
        }
        for j in 0..m {
            if !straggler[j] {
                out.w[j] = 1.0 / cnt[self.code.machine_group[j]] as f64;
            }
        }
        for g in 0..groups {
            if cnt[g] > 0 {
                for &blk in &self.code.group_blocks[g] {
                    out.alpha[blk] = 1.0;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Uncoded baseline: use whatever arrived, unscaled or 1/(1-p)-scaled
// ---------------------------------------------------------------------

pub struct IgnoreStragglersDecoder<'a> {
    pub a: &'a Csc,
    /// weight placed on each surviving machine (1.0, or 1/(1-p) for an
    /// unbiased variant)
    pub weight: f64,
}

impl Decoder for IgnoreStragglersDecoder<'_> {
    fn name(&self) -> String {
        "ignore-stragglers".to_string()
    }

    fn decode_into(&self, straggler: &[bool], out: &mut Decoding) {
        assert_eq!(straggler.len(), self.a.cols);
        out.reset(self.a.cols, self.a.rows);
        for (j, &s) in straggler.iter().enumerate() {
            if !s {
                out.w[j] = self.weight;
            }
        }
        self.a.mul_vec_into(&out.w, &mut out.alpha);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FrcCode, GradientCode, GraphCode};
    use crate::graphs::{cycle_graph, random_regular_graph};
    use crate::linalg::dist2_sq;
    use crate::prng::Rng;

    /// Graph decoder's (w, alpha) must satisfy alpha = A w exactly and
    /// match the LSQR decoder's alpha (the argmin is unique in alpha).
    #[test]
    fn graph_decoder_matches_lsqr_on_random_patterns() {
        let mut rng = Rng::new(3);
        for trial in 0..30 {
            let g = random_regular_graph(12, 3, &mut rng);
            let code = GraphCode::new("t", g);
            let m = code.n_machines();
            let straggler = rng.bernoulli_mask(m, 0.35);
            let gd = OptimalGraphDecoder::new(&code.graph).decode(&straggler);
            let ld = GenericOptimalDecoder::new(code.assignment()).decode(&straggler);
            // consistency alpha = A w
            let aw = code.assignment().mul_vec(&gd.w);
            assert!(dist2_sq(&aw, &gd.alpha) < 1e-16, "trial {trial}: alpha != A w");
            // agreement with LSQR
            assert!(
                dist2_sq(&gd.alpha, &ld.alpha) < 1e-12,
                "trial {trial}: graph {:?} vs lsqr {:?}",
                gd.alpha,
                ld.alpha
            );
            // stragglers have zero weight
            for j in 0..m {
                if straggler[j] {
                    assert_eq!(gd.w[j], 0.0);
                }
            }
        }
    }

    #[test]
    fn graph_decoder_no_stragglers_exact() {
        let g = cycle_graph(5); // odd cycle: non-bipartite
        let d = OptimalGraphDecoder::new(&g).decode(&vec![false; 5]);
        assert!(d.error_sq() < 1e-18);
        // all weights 0.5 reproduce alpha=1 on C5? any w with A w = 1 is
        // fine; just check the identity
        let aw = g.assignment_matrix().mul_vec(&d.w);
        assert!(crate::linalg::dist_to_ones_sq(&aw) < 1e-18);
    }

    #[test]
    fn graph_decoder_even_cycle_balanced() {
        let g = cycle_graph(6);
        // kill one machine: path of 6 vertices -> balanced bipartite
        let mut s = vec![false; 6];
        s[0] = true;
        let d = OptimalGraphDecoder::new(&g).decode(&s);
        assert!(d.error_sq() < 1e-18, "err={}", d.error_sq());
    }

    #[test]
    fn frc_decoder_agrees_with_lsqr() {
        let code = FrcCode::new(12, 12, 3);
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let s = rng.bernoulli_mask(12, 0.4);
            let fd = FrcOptimalDecoder::new(&code).decode(&s);
            let ld = GenericOptimalDecoder::new(code.assignment()).decode(&s);
            assert!(dist2_sq(&fd.alpha, &ld.alpha) < 1e-12);
        }
    }

    #[test]
    fn frc_decode_into_matches_closed_form() {
        let code = FrcCode::new(16, 24, 3);
        let mut rng = Rng::new(21);
        let dec = FrcOptimalDecoder::new(&code);
        let mut out = Decoding::empty();
        for _ in 0..40 {
            let s = rng.bernoulli_mask(24, 0.45);
            dec.decode_into(&s, &mut out);
            let (w, alpha) = code.optimal_decode(&s);
            assert_eq!(out.w, w);
            assert_eq!(out.alpha, alpha);
        }
    }

    #[test]
    fn fixed_decoder_is_unbiased_in_expectation() {
        let mut rng = Rng::new(5);
        let code = GraphCode::random_regular(16, 4, &mut rng);
        let p = 0.25;
        let dec = FixedDecoder::new(code.assignment(), p);
        let mut mean = vec![0.0; 16];
        let trials = 20_000;
        let mut d = Decoding::empty();
        for _ in 0..trials {
            let s = rng.bernoulli_mask(code.n_machines(), p);
            dec.decode_into(&s, &mut d);
            for i in 0..16 {
                mean[i] += d.alpha[i];
            }
        }
        for i in 0..16 {
            let m = mean[i] / trials as f64;
            assert!((m - 1.0).abs() < 0.03, "E[alpha_{i}]={m}");
        }
    }

    #[test]
    fn lsqr_decoder_all_straggle() {
        let code = FrcCode::new(6, 6, 2);
        let d = GenericOptimalDecoder::new(code.assignment()).decode(&vec![true; 6]);
        assert!(d.alpha.iter().all(|&a| a == 0.0));
        assert_eq!(d.error_sq(), 6.0);
    }

    #[test]
    fn ignore_stragglers_alpha_counts_copies() {
        let code = FrcCode::new(4, 4, 2); // 2 groups of 2 machines, 2 blocks each
        let d = IgnoreStragglersDecoder { a: code.assignment(), weight: 1.0 }
            .decode(&vec![false; 4]);
        // every block held twice with weight 1 -> alpha = 2
        assert!(d.alpha.iter().all(|&a| (a - 2.0).abs() < 1e-12));
    }

    /// Warm-started decodes must stay optimal: a long mask sequence on
    /// one (stateful) decoder agrees with a cold decoder built fresh
    /// per mask, to LSQR tolerance, and stragglers keep exactly zero
    /// weight.
    #[test]
    fn warm_started_lsqr_stays_optimal() {
        let mut rng = Rng::new(6);
        let code = GraphCode::random_regular(14, 4, &mut rng);
        let a = code.assignment();
        let warm = GenericOptimalDecoder::new(a);
        let mut out = Decoding::empty();
        // small p so consecutive masks are close and the warm path runs
        for trial in 0..40 {
            let mask = rng.bernoulli_mask(a.cols, 0.12);
            warm.decode_into(&mask, &mut out);
            let cold = GenericOptimalDecoder::new(a).decode(&mask);
            assert!(
                dist2_sq(&out.alpha, &cold.alpha) < 1e-10,
                "trial {trial}: warm vs cold alpha {:e}",
                dist2_sq(&out.alpha, &cold.alpha)
            );
            for j in 0..a.cols {
                if mask[j] {
                    assert_eq!(out.w[j], 0.0, "trial {trial}: straggler {j} got weight");
                }
            }
        }
    }

    /// Degree-diagonal preconditioning must not change the minimizer:
    /// preconditioned and plain decodes agree on alpha (unique at the
    /// optimum) to LSQR tolerance, on a heterogeneous-degree code where
    /// the preconditioner actually rescales, warm-started or not.
    #[test]
    fn preconditioned_lsqr_matches_plain_alpha() {
        let mut rng = Rng::new(31);
        // rBGC columns have binomial (non-uniform) degrees
        let code = crate::codes::RbgcCode::new(16, 24, 4, &mut rng);
        let a = code.assignment();
        let plain = GenericOptimalDecoder::new(a);
        let pre = GenericOptimalDecoder::new(a).with_precond(true);
        let mut po = Decoding::empty();
        let mut qo = Decoding::empty();
        for trial in 0..25 {
            // small p keeps the warm path live on both decoders
            let mask = rng.bernoulli_mask(a.cols, 0.15);
            plain.decode_into(&mask, &mut po);
            pre.decode_into(&mask, &mut qo);
            assert!(
                dist2_sq(&po.alpha, &qo.alpha) < 1e-10,
                "trial {trial}: precond vs plain alpha {:e}",
                dist2_sq(&po.alpha, &qo.alpha)
            );
            for j in 0..a.cols {
                if mask[j] {
                    assert_eq!(qo.w[j], 0.0, "trial {trial}: straggler {j} got weight");
                }
            }
        }
        // all-straggler masks still short-circuit cleanly
        pre.decode_into(&vec![true; a.cols], &mut qo);
        assert!(qo.alpha.iter().all(|&x| x == 0.0));
        assert_eq!(pre.last_lsqr_iterations(), 0);
    }

    /// `precond = false` is the default and must leave the historical
    /// behavior untouched: a toggled-off decoder decodes bit-identically
    /// to one built before the option existed (same struct defaults).
    #[test]
    fn precond_off_is_bitwise_default_path() {
        let mut rng = Rng::new(32);
        let code = GraphCode::random_regular(12, 3, &mut rng);
        let a = code.assignment();
        let d1 = GenericOptimalDecoder::new(a);
        let d2 = GenericOptimalDecoder::new(a).with_precond(false);
        let mut o1 = Decoding::empty();
        let mut o2 = Decoding::empty();
        for _ in 0..10 {
            let mask = rng.bernoulli_mask(a.cols, 0.2);
            d1.decode_into(&mask, &mut o1);
            d2.decode_into(&mask, &mut o2);
            for (x, y) in o1.w.iter().zip(&o2.w) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in o1.alpha.iter().zip(&o2.alpha) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// Flipping (almost) the whole mask must trigger the cold restart
    /// and still decode correctly.
    #[test]
    fn warm_start_restart_on_large_mask_change() {
        let mut rng = Rng::new(7);
        let code = GraphCode::random_regular(12, 3, &mut rng);
        let a = code.assignment();
        let dec = GenericOptimalDecoder::new(a);
        let m = a.cols;
        let mut out = Decoding::empty();
        let mask1: Vec<bool> = (0..m).map(|j| j % 2 == 0).collect();
        dec.decode_into(&mask1, &mut out);
        let mask2: Vec<bool> = (0..m).map(|j| j % 2 == 1).collect(); // full flip
        dec.decode_into(&mask2, &mut out);
        let cold = GenericOptimalDecoder::new(a).decode(&mask2);
        assert!(dist2_sq(&out.alpha, &cold.alpha) < 1e-10);
    }
}
