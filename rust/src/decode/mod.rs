//! Decoders: from a straggler pattern to coefficients (w, alpha).
//!
//! * [`OptimalGraphDecoder`] — the paper's linear-time optimal decoder
//!   for graph schemes (Section III): connected components of the
//!   surviving subgraph determine alpha*, and a spanning-tree
//!   back-substitution (plus one odd-cycle edge for non-bipartite
//!   components) produces a w* with A w* = alpha*. O(n + m) per decode,
//!   "the same order as computing the update itself".
//! * [`GenericOptimalDecoder`] — LSQR on the surviving columns,
//!   w* = argmin |A_S w - 1|_2 (Eq. 3) for arbitrary assignments.
//! * [`FixedDecoder`] — w_j = 1/(d (1-p)) on survivors (unbiased fixed
//!   coefficients, Section VIII).
//! * [`FrcOptimalDecoder`] — closed form for FRC group structure.
//! * [`IgnoreStragglersDecoder`] — the uncoded baseline.

use crate::codes::FrcCode;
use crate::graphs::Graph;
use crate::sparse::{lsqr, ColumnSubsetOp, Csc};

/// A decoded coefficient pair: per-machine weights w (zero on
/// stragglers) and the induced per-block alpha = A w.
#[derive(Clone, Debug)]
pub struct Decoding {
    pub w: Vec<f64>,
    pub alpha: Vec<f64>,
}

impl Decoding {
    /// The paper's decoding error |alpha - 1|_2^2.
    pub fn error_sq(&self) -> f64 {
        crate::linalg::dist_to_ones_sq(&self.alpha)
    }
}

/// `straggler[j] == true` means machine j's result never arrived.
pub trait Decoder {
    fn decode(&self, straggler: &[bool]) -> Decoding;
    fn name(&self) -> String;
}

// ---------------------------------------------------------------------
// Optimal graph decoder (Section III)
// ---------------------------------------------------------------------

pub struct OptimalGraphDecoder<'a> {
    pub g: &'a Graph,
    /// reusable scratch so repeated decodes are allocation-free on the
    /// hot path (the paper's "c*m operations" claim — §Perf)
    scratch: std::cell::RefCell<Scratch>,
}

#[derive(Default)]
struct Scratch {
    /// BFS discovery order; doubles as the queue
    order: Vec<usize>,
    /// usize::MAX = unvisited; otherwise component id
    comp_of: Vec<usize>,
    color: Vec<u8>,
    parent_edge: Vec<usize>,
    incident: Vec<f64>,
}

impl<'a> OptimalGraphDecoder<'a> {
    pub fn new(g: &'a Graph) -> Self {
        Self { g, scratch: std::cell::RefCell::new(Scratch::default()) }
    }
}

impl Decoder for OptimalGraphDecoder<'_> {
    fn name(&self) -> String {
        "optimal-graph".to_string()
    }

    /// Single-pass linear-time decode (Section III): one BFS splits the
    /// surviving subgraph into components and 2-colors them; alpha* is
    /// set per component (1/1 if an odd cycle exists, side-imbalance
    /// values if bipartite, 0 if isolated); w* follows by leaf-up
    /// spanning-tree substitution, with one odd non-tree edge carrying
    /// the color imbalance in non-bipartite components.
    fn decode(&self, straggler: &[bool]) -> Decoding {
        let g = self.g;
        let (n, m) = (g.n, g.m());
        assert_eq!(straggler.len(), m);
        let mut s = self.scratch.borrow_mut();
        s.order.clear();
        s.comp_of.clear();
        s.comp_of.resize(n, usize::MAX);
        s.color.resize(n, 0);
        s.parent_edge.resize(n, usize::MAX);
        s.incident.resize(n, 0.0);
        let Scratch { order, comp_of, color, parent_edge, incident } = &mut *s;

        let mut w = vec![0.0; m];
        let mut alpha = vec![0.0; n];

        for root in 0..n {
            if comp_of[root] != usize::MAX {
                continue;
            }
            let start = order.len();
            let cid = root; // any unique id per component
            comp_of[root] = cid;
            color[root] = 0;
            parent_edge[root] = usize::MAX;
            incident[root] = 0.0;
            order.push(root);
            // BFS; track 2-coloring, side counts, and one odd edge
            let (mut c0, mut c1) = (1usize, 0usize);
            let mut odd_edge = usize::MAX;
            let mut head = start;
            while head < order.len() {
                let u = order[head];
                head += 1;
                for &(v, eid) in &g.adj[u] {
                    if straggler[eid] {
                        continue;
                    }
                    if comp_of[v] == usize::MAX {
                        comp_of[v] = cid;
                        color[v] = 1 - color[u];
                        parent_edge[v] = eid;
                        incident[v] = 0.0;
                        if color[v] == 0 {
                            c0 += 1;
                        } else {
                            c1 += 1;
                        }
                        order.push(v);
                    } else if color[v] == color[u] && odd_edge == usize::MAX {
                        odd_edge = eid; // an odd (non-tree) edge
                    }
                }
            }
            let size = order.len() - start;
            if size == 1 {
                // isolated block: alpha stays 0, no weights
                continue;
            }
            // per-component alpha values (Section III obs. 1-3)
            let (a0, a1) = if odd_edge != usize::MAX {
                (1.0, 1.0)
            } else {
                let tot = (c0 + c1) as f64;
                (2.0 * c1 as f64 / tot, 2.0 * c0 as f64 / tot)
            };
            for &v in &order[start..] {
                alpha[v] = if color[v] == 0 { a0 } else { a1 };
            }
            if odd_edge != usize::MAX {
                // imbalance of targets across colors: alpha = 1 here, so
                // it is simply c0 - c1
                let imbalance = c0 as f64 - c1 as f64;
                let (u, v) = g.edges[odd_edge];
                let t = if color[u] == 0 { imbalance / 2.0 } else { -imbalance / 2.0 };
                w[odd_edge] = t;
                incident[u] += t;
                incident[v] += t;
            }
            // leaf-up substitution: each non-root vertex fixes its
            // parent edge so its incident sum reaches alpha[v]
            for idx in (start + 1..start + size).rev() {
                let v = order[idx];
                let e = parent_edge[v];
                let (x, y) = g.edges[e];
                let parent = if x == v { y } else { x };
                let we = alpha[v] - incident[v];
                w[e] += we;
                incident[v] += we;
                incident[parent] += we;
            }
            debug_assert!(
                (incident[order[start]] - alpha[order[start]]).abs() < 1e-6,
                "root constraint violated"
            );
        }
        Decoding { w, alpha }
    }
}

// ---------------------------------------------------------------------
// Generic optimal decoder (Eq. 3 via LSQR)
// ---------------------------------------------------------------------

pub struct GenericOptimalDecoder<'a> {
    pub a: &'a Csc,
    pub atol: f64,
    pub max_iter: usize,
}

impl<'a> GenericOptimalDecoder<'a> {
    pub fn new(a: &'a Csc) -> Self {
        Self { a, atol: 1e-12, max_iter: 4 * (a.rows + a.cols) }
    }
}

impl Decoder for GenericOptimalDecoder<'_> {
    fn name(&self) -> String {
        "optimal-lsqr".to_string()
    }

    fn decode(&self, straggler: &[bool]) -> Decoding {
        let m = self.a.cols;
        assert_eq!(straggler.len(), m);
        let cols: Vec<usize> = (0..m).filter(|&j| !straggler[j]).collect();
        let mut w = vec![0.0; m];
        if cols.is_empty() {
            return Decoding { w, alpha: vec![0.0; self.a.rows] };
        }
        let op = ColumnSubsetOp { a: self.a, cols: &cols };
        let ones = vec![1.0; self.a.rows];
        let res = lsqr(&op, &ones, self.atol, self.max_iter);
        for (jj, &j) in cols.iter().enumerate() {
            w[j] = res.x[jj];
        }
        let alpha = self.a.mul_vec(&w);
        Decoding { w, alpha }
    }
}

// ---------------------------------------------------------------------
// Fixed-coefficient decoder (unbiased): w_j = 1 / (d (1 - p))
// ---------------------------------------------------------------------

pub struct FixedDecoder<'a> {
    pub a: &'a Csc,
    /// replication factor d used in the normalization
    pub d: f64,
    /// straggler probability the coefficients are calibrated for
    pub p: f64,
}

impl<'a> FixedDecoder<'a> {
    pub fn new(a: &'a Csc, p: f64) -> Self {
        Self { a, d: a.replication_factor(), p }
    }
}

impl Decoder for FixedDecoder<'_> {
    fn name(&self) -> String {
        "fixed".to_string()
    }

    fn decode(&self, straggler: &[bool]) -> Decoding {
        let coeff = 1.0 / (self.d * (1.0 - self.p));
        let w: Vec<f64> = straggler.iter().map(|&s| if s { 0.0 } else { coeff }).collect();
        let alpha = self.a.mul_vec(&w);
        Decoding { w, alpha }
    }
}

// ---------------------------------------------------------------------
// FRC closed-form optimal decoder
// ---------------------------------------------------------------------

pub struct FrcOptimalDecoder<'a> {
    pub code: &'a FrcCode,
}

impl Decoder for FrcOptimalDecoder<'_> {
    fn name(&self) -> String {
        "optimal-frc".to_string()
    }

    fn decode(&self, straggler: &[bool]) -> Decoding {
        let (w, alpha) = self.code.optimal_decode(straggler);
        Decoding { w, alpha }
    }
}

// ---------------------------------------------------------------------
// Uncoded baseline: use whatever arrived, unscaled or 1/(1-p)-scaled
// ---------------------------------------------------------------------

pub struct IgnoreStragglersDecoder<'a> {
    pub a: &'a Csc,
    /// weight placed on each surviving machine (1.0, or 1/(1-p) for an
    /// unbiased variant)
    pub weight: f64,
}

impl Decoder for IgnoreStragglersDecoder<'_> {
    fn name(&self) -> String {
        "ignore-stragglers".to_string()
    }

    fn decode(&self, straggler: &[bool]) -> Decoding {
        let w: Vec<f64> = straggler
            .iter()
            .map(|&s| if s { 0.0 } else { self.weight })
            .collect();
        let alpha = self.a.mul_vec(&w);
        Decoding { w, alpha }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FrcCode, GradientCode, GraphCode};
    use crate::graphs::{cycle_graph, random_regular_graph};
    use crate::linalg::dist2_sq;
    use crate::prng::Rng;

    /// Graph decoder's (w, alpha) must satisfy alpha = A w exactly and
    /// match the LSQR decoder's alpha (the argmin is unique in alpha).
    #[test]
    fn graph_decoder_matches_lsqr_on_random_patterns() {
        let mut rng = Rng::new(3);
        for trial in 0..30 {
            let g = random_regular_graph(12, 3, &mut rng);
            let code = GraphCode::new("t", g);
            let m = code.n_machines();
            let straggler = rng.bernoulli_mask(m, 0.35);
            let gd = OptimalGraphDecoder::new(&code.graph).decode(&straggler);
            let ld = GenericOptimalDecoder::new(code.assignment()).decode(&straggler);
            // consistency alpha = A w
            let aw = code.assignment().mul_vec(&gd.w);
            assert!(dist2_sq(&aw, &gd.alpha) < 1e-16, "trial {trial}: alpha != A w");
            // agreement with LSQR
            assert!(
                dist2_sq(&gd.alpha, &ld.alpha) < 1e-12,
                "trial {trial}: graph {:?} vs lsqr {:?}",
                gd.alpha,
                ld.alpha
            );
            // stragglers have zero weight
            for j in 0..m {
                if straggler[j] {
                    assert_eq!(gd.w[j], 0.0);
                }
            }
        }
    }

    #[test]
    fn graph_decoder_no_stragglers_exact() {
        let g = cycle_graph(5); // odd cycle: non-bipartite
        let d = OptimalGraphDecoder::new(&g).decode(&vec![false; 5]);
        assert!(d.error_sq() < 1e-18);
        // all weights 0.5 reproduce alpha=1 on C5? any w with A w = 1 is
        // fine; just check the identity
        let aw = g.assignment_matrix().mul_vec(&d.w);
        assert!(crate::linalg::dist_to_ones_sq(&aw) < 1e-18);
    }

    #[test]
    fn graph_decoder_even_cycle_balanced() {
        let g = cycle_graph(6);
        // kill one machine: path of 6 vertices -> balanced bipartite
        let mut s = vec![false; 6];
        s[0] = true;
        let d = OptimalGraphDecoder::new(&g).decode(&s);
        assert!(d.error_sq() < 1e-18, "err={}", d.error_sq());
    }

    #[test]
    fn frc_decoder_agrees_with_lsqr() {
        let code = FrcCode::new(12, 12, 3);
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let s = rng.bernoulli_mask(12, 0.4);
            let fd = FrcOptimalDecoder { code: &code }.decode(&s);
            let ld = GenericOptimalDecoder::new(code.assignment()).decode(&s);
            assert!(dist2_sq(&fd.alpha, &ld.alpha) < 1e-12);
        }
    }

    #[test]
    fn fixed_decoder_is_unbiased_in_expectation() {
        let mut rng = Rng::new(5);
        let code = GraphCode::random_regular(16, 4, &mut rng);
        let p = 0.25;
        let dec = FixedDecoder::new(code.assignment(), p);
        let mut mean = vec![0.0; 16];
        let trials = 20_000;
        for _ in 0..trials {
            let s = rng.bernoulli_mask(code.n_machines(), p);
            let d = dec.decode(&s);
            for i in 0..16 {
                mean[i] += d.alpha[i];
            }
        }
        for i in 0..16 {
            let m = mean[i] / trials as f64;
            assert!((m - 1.0).abs() < 0.03, "E[alpha_{i}]={m}");
        }
    }

    #[test]
    fn lsqr_decoder_all_straggle() {
        let code = FrcCode::new(6, 6, 2);
        let d = GenericOptimalDecoder::new(code.assignment()).decode(&vec![true; 6]);
        assert!(d.alpha.iter().all(|&a| a == 0.0));
        assert_eq!(d.error_sq(), 6.0);
    }

    #[test]
    fn ignore_stragglers_alpha_counts_copies() {
        let code = FrcCode::new(4, 4, 2); // 2 groups of 2 machines, 2 blocks each
        let d = IgnoreStragglersDecoder { a: code.assignment(), weight: 1.0 }
            .decode(&vec![false; 4]);
        // every block held twice with weight 1 -> alpha = 2
        assert!(d.alpha.iter().all(|&a| (a - 2.0).abs() < 1e-12));
    }
}
