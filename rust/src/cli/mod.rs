//! CLI argument-parsing substrate (no clap in the offline build).
//!
//! Subcommand-style parser for the `gcod` launcher and the examples:
//! `gcod <command> [--flag value] [--switch] [--set key=value ...]`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declarative flag spec.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    /// switches take no value
    pub is_switch: bool,
}

/// One subcommand with its flags.
#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub flags: Vec<FlagSpec>,
}

/// Parsed invocation.
#[derive(Debug, Clone)]
pub struct Invocation {
    pub command: String,
    values: BTreeMap<String, String>,
    /// every explicit --flag value occurrence, in argv order (defaults
    /// are not recorded here) — for repeatable flags like `--input`
    repeated: Vec<(String, String)>,
    switches: Vec<String>,
    /// --set key=value overrides, applied to Settings by the caller
    pub overrides: Vec<String>,
}

impl Invocation {
    pub fn get(&self, flag: &str) -> Option<&str> {
        self.values.get(flag).map(|s| s.as_str())
    }

    /// All explicitly-passed values of a repeatable flag, in argv
    /// order. Empty if the flag was never passed (defaults don't
    /// count); [`Invocation::get`] still returns the last occurrence
    /// (or the default).
    pub fn get_all(&self, flag: &str) -> Vec<&str> {
        self.repeated
            .iter()
            .filter(|(name, _)| name == flag)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn str_or(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, flag: &str, default: u64) -> u64 {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn switch(&self, flag: &str) -> bool {
        self.switches.iter().any(|s| s == flag)
    }

    /// The `--set key=value` overrides as a sorted map (later
    /// occurrences of a key win). Shared by the sweep subcommands,
    /// whose extra parameters travel as `--set` pairs.
    pub fn override_map(&self) -> Result<BTreeMap<String, String>, CliError> {
        let mut map = BTreeMap::new();
        for ov in &self.overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| CliError(format!("--set needs key=value, got '{ov}'")))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(map)
    }
}

/// Application = a set of subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl App {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [flags]\n\nCOMMANDS:\n",
                            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<18} {}\n", c.name, c.help));
        }
        s.push_str("\nRun '<command> --help' for flags.\n");
        s
    }

    pub fn command_usage(&self, cmd: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nFLAGS:\n", self.name, cmd.name, cmd.help);
        for f in &cmd.flags {
            let d = f
                .default
                .map(|d| format!(" (default {d})"))
                .unwrap_or_default();
            let v = if f.is_switch { "" } else { " <value>" };
            s.push_str(&format!("  --{}{v:<10} {}{d}\n", f.name, f.help));
        }
        s.push_str("  --set key=value   override a config setting (repeatable)\n");
        s
    }

    /// Parse argv (without the binary name). Returns Err with a usage
    /// string on bad input or help requests.
    pub fn parse(&self, argv: &[String]) -> Result<Invocation, CliError> {
        let cmd_name = argv.first().ok_or_else(|| CliError(self.usage()))?;
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError(self.usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError(format!("unknown command '{cmd_name}'\n\n{}", self.usage())))?;

        let mut inv = Invocation {
            command: cmd_name.clone(),
            values: BTreeMap::new(),
            repeated: Vec::new(),
            switches: Vec::new(),
            overrides: Vec::new(),
        };
        // defaults
        for f in &cmd.flags {
            if let Some(d) = f.default {
                inv.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.command_usage(cmd)));
            }
            let name = arg
                .strip_prefix("--")
                .ok_or_else(|| CliError(format!("expected a --flag, got '{arg}'")))?;
            if name == "set" {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| CliError("--set needs key=value".into()))?;
                inv.overrides.push(v.clone());
                i += 2;
                continue;
            }
            let spec = cmd
                .flags
                .iter()
                .find(|f| f.name == name)
                .ok_or_else(|| {
                    CliError(format!("unknown flag --{name}\n\n{}", self.command_usage(cmd)))
                })?;
            if spec.is_switch {
                inv.switches.push(name.to_string());
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| CliError(format!("--{name} needs a value")))?;
                inv.values.insert(name.to_string(), v.clone());
                inv.repeated.push((name.to_string(), v.clone()));
                i += 2;
            }
        }
        Ok(inv)
    }
}

/// Shorthand for building flag specs.
pub fn flag(name: &'static str, help: &'static str, default: Option<&'static str>) -> FlagSpec {
    FlagSpec { name, help, default, is_switch: false }
}

pub fn switch(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, default: None, is_switch: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "gcod",
            about: "test",
            commands: vec![CommandSpec {
                name: "train",
                help: "run training",
                flags: vec![
                    flag("p", "straggler rate", Some("0.1")),
                    flag("iters", "iterations", Some("50")),
                    switch("verbose", "log more"),
                ],
            }],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let inv = app().parse(&argv(&["train", "--p", "0.25", "--verbose"])).unwrap();
        assert_eq!(inv.command, "train");
        assert_eq!(inv.f64_or("p", 0.0), 0.25);
        assert_eq!(inv.usize_or("iters", 0), 50); // default
        assert!(inv.switch("verbose"));
        assert!(!inv.switch("other"));
    }

    #[test]
    fn repeated_flags_collect_in_order() {
        let inv = app()
            .parse(&argv(&["train", "--p", "0.1", "--p", "0.3", "--iters", "9"]))
            .unwrap();
        assert_eq!(inv.get_all("p"), vec!["0.1", "0.3"]);
        assert_eq!(inv.get_all("iters"), vec!["9"]);
        // defaults never appear in get_all, get() sees the last value
        assert!(inv.get_all("missing").is_empty());
        let with_default = app().parse(&argv(&["train"])).unwrap();
        assert!(with_default.get_all("iters").is_empty());
        assert_eq!(with_default.usize_or("iters", 0), 50);
        assert_eq!(inv.f64_or("p", 0.0), 0.3);
    }

    #[test]
    fn set_overrides_collect() {
        let inv = app()
            .parse(&argv(&["train", "--set", "a.b=1", "--set", "c=2"]))
            .unwrap();
        assert_eq!(inv.overrides, vec!["a.b=1", "c=2"]);
        let map = inv.override_map().unwrap();
        assert_eq!(map.get("a.b").map(String::as_str), Some("1"));
        assert_eq!(map.get("c").map(String::as_str), Some("2"));
        // malformed pairs are an error, later keys win
        let inv = app()
            .parse(&argv(&["train", "--set", "k=1", "--set", "k=2"]))
            .unwrap();
        assert_eq!(inv.override_map().unwrap().get("k").map(String::as_str), Some("2"));
        let inv = app().parse(&argv(&["train", "--set", "oops"])).unwrap();
        assert!(inv.override_map().is_err());
    }

    #[test]
    fn errors_are_usage_shaped() {
        assert!(app().parse(&argv(&[])).is_err());
        assert!(app().parse(&argv(&["nope"])).is_err());
        assert!(app().parse(&argv(&["train", "--bogus", "1"])).is_err());
        assert!(app().parse(&argv(&["train", "--p"])).is_err());
        let help = app().parse(&argv(&["train", "--help"])).unwrap_err();
        assert!(help.0.contains("straggler rate"));
    }
}
