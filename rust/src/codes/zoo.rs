//! Scheme registry: build any (code, decoder) pair from a spec string.
//!
//! Benches, examples and the CLI all construct experiment arms through
//! this zoo so the paper's scheme lineup (§VIII: four coded schemes +
//! uncoded, in two parameter regimes) is defined in exactly one place.

use super::{
    BibdCode, BrcCode, ExpanderAdjacencyCode, FrcCode, GradientCode, GraphCode,
    PairwiseBalancedCode, RbgcCode, UncodedCode,
};
use crate::decode::{
    Decoder, FixedDecoder, FrcOptimalDecoder, GenericOptimalDecoder, IgnoreStragglersDecoder,
    OptimalGraphDecoder,
};
use crate::graphs::Graph;
use crate::linalg::LinalgBackend;
use crate::prng::Rng;
use crate::sparse::Csc;

/// Which assignment scheme to build.
#[derive(Clone, Debug, PartialEq)]
pub enum SchemeSpec {
    /// the paper's A_1: random d-regular graph on n vertices
    GraphRandomRegular { n: usize, d: usize },
    /// the paper's A_2: LPS Ramanujan graph X^{p,q}
    GraphLps { p: u64, q: u64 },
    /// FRC of Tandon et al. [4]
    Frc { n: usize, m: usize, d: usize },
    /// Raviv et al. [6] adjacency code on a random d-regular graph
    ExpanderAdj { n: usize, d: usize },
    /// Kadhe et al. [7] projective-plane BIBD of order s
    Bibd { s: usize },
    /// Charles et al. [8] regularized Bernoulli code
    Rbgc { n: usize, m: usize, d: usize },
    /// Wang et al. [9] batch raptor code
    Brc { n: usize, m: usize, batch: usize },
    /// Bitar et al. [5] pairwise balanced
    Pairwise { n: usize, m: usize, d: usize },
    Uncoded { n: usize },
}

impl SchemeSpec {
    /// Parse a CLI spec like "graph-rr:16,3", "lps:5,13", "frc:16,24,3",
    /// "expander:24,3", "bibd:3", "rbgc:16,24,3", "brc:16,24,4",
    /// "pairwise:16,24,3", "uncoded:24".
    pub fn parse(s: &str) -> Result<Self, String> {
        let (kind, args) = s.split_once(':').unwrap_or((s, ""));
        let nums: Vec<usize> = if args.is_empty() {
            vec![]
        } else {
            args.split(',')
                .map(|x| x.trim().parse::<usize>().map_err(|e| format!("bad arg '{x}': {e}")))
                .collect::<Result<_, _>>()?
        };
        let need = |k: usize| -> Result<(), String> {
            if nums.len() == k {
                Ok(())
            } else {
                Err(format!("scheme '{kind}' needs {k} args, got {}", nums.len()))
            }
        };
        Ok(match kind {
            "graph-rr" => {
                need(2)?;
                SchemeSpec::GraphRandomRegular { n: nums[0], d: nums[1] }
            }
            "lps" => {
                need(2)?;
                SchemeSpec::GraphLps { p: nums[0] as u64, q: nums[1] as u64 }
            }
            "frc" => {
                need(3)?;
                SchemeSpec::Frc { n: nums[0], m: nums[1], d: nums[2] }
            }
            "expander" => {
                need(2)?;
                SchemeSpec::ExpanderAdj { n: nums[0], d: nums[1] }
            }
            "bibd" => {
                need(1)?;
                SchemeSpec::Bibd { s: nums[0] }
            }
            "rbgc" => {
                need(3)?;
                SchemeSpec::Rbgc { n: nums[0], m: nums[1], d: nums[2] }
            }
            "brc" => {
                need(3)?;
                SchemeSpec::Brc { n: nums[0], m: nums[1], batch: nums[2] }
            }
            "pairwise" => {
                need(3)?;
                SchemeSpec::Pairwise { n: nums[0], m: nums[1], d: nums[2] }
            }
            "uncoded" => {
                need(1)?;
                SchemeSpec::Uncoded { n: nums[0] }
            }
            _ => return Err(format!("unknown scheme kind '{kind}'")),
        })
    }
}

/// A constructed scheme with whatever structure its decoders need.
pub struct BuiltScheme {
    pub name: String,
    pub a: Csc,
    pub graph: Option<Graph>,
    pub frc: Option<FrcCode>,
}

impl BuiltScheme {
    pub fn n_blocks(&self) -> usize {
        self.a.rows
    }
    pub fn n_machines(&self) -> usize {
        self.a.cols
    }
    pub fn replication(&self) -> f64 {
        self.a.replication_factor()
    }
}

pub fn build(spec: &SchemeSpec, rng: &mut Rng) -> BuiltScheme {
    match spec {
        SchemeSpec::GraphRandomRegular { n, d } => {
            let c = GraphCode::random_regular(*n, *d, rng);
            BuiltScheme {
                name: c.name(),
                a: c.assignment().clone(),
                graph: Some(c.graph),
                frc: None,
            }
        }
        SchemeSpec::GraphLps { p, q } => {
            let c = GraphCode::lps(*p, *q);
            BuiltScheme {
                name: c.name(),
                a: c.assignment().clone(),
                graph: Some(c.graph),
                frc: None,
            }
        }
        SchemeSpec::Frc { n, m, d } => {
            let c = FrcCode::new(*n, *m, *d);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: Some(c) }
        }
        SchemeSpec::ExpanderAdj { n, d } => {
            let c = ExpanderAdjacencyCode::random_regular(*n, *d, rng);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: None }
        }
        SchemeSpec::Bibd { s } => {
            let c = BibdCode::projective_plane(*s);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: None }
        }
        SchemeSpec::Rbgc { n, m, d } => {
            let c = RbgcCode::new(*n, *m, *d, rng);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: None }
        }
        SchemeSpec::Brc { n, m, batch } => {
            let c = BrcCode::new(*n, *m, *batch, rng);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: None }
        }
        SchemeSpec::Pairwise { n, m, d } => {
            let c = PairwiseBalancedCode::new(*n, *m, *d, rng);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: None }
        }
        SchemeSpec::Uncoded { n } => {
            let c = UncodedCode::new(*n);
            BuiltScheme { name: c.name(), a: c.assignment().clone(), graph: None, frc: None }
        }
    }
}

/// Decoding strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecoderSpec {
    /// best available optimal decoder: linear-time for graph schemes,
    /// closed form for FRC, LSQR otherwise
    Optimal,
    /// force the generic LSQR optimal decoder (cross-checking)
    OptimalLsqr,
    /// fixed unbiased coefficients 1/(d(1-p))
    Fixed,
    /// uncoded-style: weight 1 on every survivor
    Ignore,
}

impl DecoderSpec {
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s {
            "optimal" => DecoderSpec::Optimal,
            "optimal-lsqr" => DecoderSpec::OptimalLsqr,
            "fixed" => DecoderSpec::Fixed,
            "ignore" => DecoderSpec::Ignore,
            _ => return Err(format!("unknown decoder '{s}' (optimal|optimal-lsqr|fixed|ignore)")),
        })
    }
}

/// Build the decoder for a scheme. `p` calibrates fixed coefficients.
/// Equivalent to [`make_decoder_opts`] with preconditioning off.
pub fn make_decoder<'a>(
    scheme: &'a BuiltScheme,
    spec: DecoderSpec,
    p: f64,
) -> Box<dyn Decoder + 'a> {
    make_decoder_opts(scheme, spec, p, false)
}

/// [`make_decoder`] with decoder options: `precond` enables the
/// degree-diagonal LSQR preconditioner on the generic optimal decoder
/// (see [`GenericOptimalDecoder::with_precond`]); it is ignored by the
/// closed-form decoders, whose solutions involve no iteration.
/// Equivalent to [`make_decoder_cfg`] on the exact linalg tier.
pub fn make_decoder_opts<'a>(
    scheme: &'a BuiltScheme,
    spec: DecoderSpec,
    p: f64,
    precond: bool,
) -> Box<dyn Decoder + 'a> {
    make_decoder_cfg(scheme, spec, p, precond, LinalgBackend::Exact)
}

/// [`make_decoder_opts`] with an explicit [`LinalgBackend`] tier for
/// the generic LSQR decoder's dense norms (see
/// [`GenericOptimalDecoder::with_backend`]). The closed-form decoders
/// (graph, FRC, fixed, ignore) involve no dense iteration and ignore
/// it — their output is tier-independent by construction.
pub fn make_decoder_cfg<'a>(
    scheme: &'a BuiltScheme,
    spec: DecoderSpec,
    p: f64,
    precond: bool,
    backend: LinalgBackend,
) -> Box<dyn Decoder + 'a> {
    match spec {
        DecoderSpec::Optimal => {
            if let Some(g) = &scheme.graph {
                Box::new(OptimalGraphDecoder::new(g))
            } else if let Some(frc) = &scheme.frc {
                Box::new(FrcOptimalDecoder::new(frc))
            } else {
                Box::new(
                    GenericOptimalDecoder::new(&scheme.a)
                        .with_precond(precond)
                        .with_backend(backend),
                )
            }
        }
        DecoderSpec::OptimalLsqr => Box::new(
            GenericOptimalDecoder::new(&scheme.a).with_precond(precond).with_backend(backend),
        ),
        DecoderSpec::Fixed => Box::new(FixedDecoder::new(&scheme.a, p)),
        DecoderSpec::Ignore => Box::new(IgnoreStragglersDecoder { a: &scheme.a, weight: 1.0 }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(
            SchemeSpec::parse("graph-rr:16,3").unwrap(),
            SchemeSpec::GraphRandomRegular { n: 16, d: 3 }
        );
        assert_eq!(SchemeSpec::parse("lps:5,13").unwrap(), SchemeSpec::GraphLps { p: 5, q: 13 });
        assert_eq!(
            SchemeSpec::parse("frc:16,24,3").unwrap(),
            SchemeSpec::Frc { n: 16, m: 24, d: 3 }
        );
        assert!(SchemeSpec::parse("bogus:1").is_err());
        assert!(SchemeSpec::parse("frc:1").is_err());
    }

    #[test]
    fn build_all_kinds() {
        let mut rng = Rng::new(0);
        for spec in [
            "graph-rr:12,3",
            "frc:12,12,3",
            "expander:12,3",
            "bibd:2",
            "rbgc:12,12,3",
            "brc:12,12,4",
            "pairwise:12,12,3",
            "uncoded:12",
        ] {
            let s = SchemeSpec::parse(spec).unwrap();
            let b = build(&s, &mut rng);
            assert!(b.n_blocks() > 0, "{spec}");
            assert!(b.n_machines() > 0, "{spec}");
            // decoders at least run
            for d in [DecoderSpec::Optimal, DecoderSpec::Fixed, DecoderSpec::Ignore] {
                let dec = make_decoder(&b, d, 0.1);
                let mask = vec![false; b.n_machines()];
                let out = dec.decode(&mask);
                assert_eq!(out.alpha.len(), b.n_blocks(), "{spec}");
            }
        }
    }

    #[test]
    fn optimal_picks_specialized_decoders() {
        let mut rng = Rng::new(1);
        let g = build(&SchemeSpec::parse("graph-rr:12,3").unwrap(), &mut rng);
        assert_eq!(make_decoder(&g, DecoderSpec::Optimal, 0.1).name(), "optimal-graph");
        let f = build(&SchemeSpec::parse("frc:12,12,3").unwrap(), &mut rng);
        assert_eq!(make_decoder(&f, DecoderSpec::Optimal, 0.1).name(), "optimal-frc");
        let e = build(&SchemeSpec::parse("expander:12,3").unwrap(), &mut rng);
        assert_eq!(make_decoder(&e, DecoderSpec::Optimal, 0.1).name(), "optimal-lsqr");
    }
}
