//! Black-box debiasing transform (paper Proposition B.1).
//!
//! Given any assignment matrix A and the per-block decoding means
//! E[alpha_i] (estimated by Monte Carlo for the scheme's decoder at a
//! given straggler rate), produce a modified assignment A-hat that is
//! *unbiased*: E[alpha-hat] = 1, at the cost of at most doubling the
//! computational load. Rows with E[alpha_i] >= delta are rescaled by
//! 1/E[alpha_i]; the matrix is then padded back to N rows by repeating
//! its first rows (so dropped low-mean rows are covered by duplicates
//! of healthy ones, exactly as in the proof).

use crate::sparse::Csc;

/// Result of debiasing: the new assignment plus, for each new row, the
/// original block it carries (so gradients can be routed).
pub struct Debiased {
    pub a: Csc,
    /// original block id served by each row of `a`
    pub row_origin: Vec<usize>,
    /// rows of the original matrix that were kept (E[alpha] >= delta)
    pub kept: Vec<usize>,
}

/// Apply Proposition B.1. `expected_alpha[i]` must be the Monte-Carlo
/// estimate of E[alpha_i]; `delta` the keep threshold (the proof uses
/// delta = 1 - sqrt(2 eps)).
pub fn debias(a: &Csc, expected_alpha: &[f64], delta: f64) -> Debiased {
    let n = a.rows;
    assert_eq!(expected_alpha.len(), n);
    assert!(delta > 0.0 && delta <= 1.0);
    let kept: Vec<usize> = (0..n).filter(|&i| expected_alpha[i] >= delta).collect();
    assert!(
        kept.len() * 2 >= n,
        "debias: fewer than half the blocks have E[alpha] >= {delta}; \
         the scheme is too biased to debias (Prop. B.1 requires |S| >= N/2)"
    );
    let s = kept.len();
    let t = n - s;
    // row_origin: kept rows then the first t kept rows again
    let mut row_origin = kept.clone();
    row_origin.extend_from_slice(&kept[..t]);

    // build triplets: new row r carries old row kept-row scaled
    let mut trip = Vec::with_capacity(a.nnz() * 2);
    // invert: for each column, for each (row, val), look up new rows
    let mut new_rows_of: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (new_r, &old_r) in row_origin.iter().enumerate() {
        new_rows_of[old_r].push(new_r);
    }
    for j in 0..a.cols {
        let (ri, vals) = a.col(j);
        for (k, &old_r) in ri.iter().enumerate() {
            let scale = 1.0 / expected_alpha[old_r];
            for &new_r in &new_rows_of[old_r] {
                trip.push((new_r, j, vals[k] * scale));
            }
        }
    }
    let _ = s;
    Debiased { a: Csc::from_triplets(n, a.cols, trip), row_origin, kept }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_mean_is_noop_scaling() {
        // A = 2x2 identity, means exactly 1 -> A-hat == A
        let a = Csc::from_triplets(2, 2, vec![(0, 0, 1.0), (1, 1, 1.0)]);
        let d = debias(&a, &[1.0, 1.0], 0.5);
        assert_eq!(d.a.to_dense(), a.to_dense());
        assert_eq!(d.row_origin, vec![0, 1]);
    }

    #[test]
    fn rescales_biased_rows() {
        let a = Csc::from_triplets(2, 2, vec![(0, 0, 1.0), (1, 1, 1.0)]);
        // block 0 decodes to 0.8 on average -> row scaled by 1.25
        let d = debias(&a, &[0.8, 1.0], 0.5);
        let dd = d.a.to_dense();
        assert!((dd[(0, 0)] - 1.25).abs() < 1e-12);
        assert_eq!(dd[(1, 1)], 1.0);
    }

    #[test]
    fn drops_and_duplicates_low_mean_rows() {
        // 4 blocks; block 3 hopeless (mean 0.1) -> dropped, row for
        // block 0 duplicated in its place
        let a = Csc::from_triplets(
            4,
            4,
            vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0), (3, 3, 1.0)],
        );
        let d = debias(&a, &[1.0, 1.0, 1.0, 0.1], 0.5);
        assert_eq!(d.kept, vec![0, 1, 2]);
        assert_eq!(d.row_origin, vec![0, 1, 2, 0]);
        let dd = d.a.to_dense();
        // new row 3 duplicates block 0's storage
        assert_eq!(dd[(3, 0)], 1.0);
        // block 3's column is now unused by any row
        for r in 0..4 {
            assert_eq!(dd[(r, 3)], 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "too biased")]
    fn rejects_hopeless_schemes() {
        let a = Csc::from_triplets(2, 2, vec![(0, 0, 1.0), (1, 1, 1.0)]);
        debias(&a, &[0.1, 0.1], 0.5);
    }

    #[test]
    fn load_at_most_doubles() {
        let a = Csc::from_triplets(
            4,
            2,
            vec![(0, 0, 1.0), (1, 0, 1.0), (2, 1, 1.0), (3, 1, 1.0)],
        );
        let d = debias(&a, &[1.0, 1.0, 1.0, 0.2], 0.6);
        assert!(d.a.max_col_nnz() <= 2 * a.max_col_nnz());
    }
}
