//! Randomized baseline codes: rBGC [8], BRC [9], pairwise-balanced [5].

use super::GradientCode;
use crate::prng::Rng;
use crate::sparse::Csc;

/// Regularized Bernoulli gradient code (Charles, Papailiopoulos &
/// Ellenberg [8]): every (block, machine) entry is 1 independently with
/// probability d/m, then "regularized" so no block has fewer than one
/// replica (empty rows get a uniformly random machine). Expected
/// replication is d.
pub struct RbgcCode {
    a: Csc,
    d: usize,
}

impl RbgcCode {
    pub fn new(n: usize, m: usize, d: usize, rng: &mut Rng) -> Self {
        let p = d as f64 / m as f64;
        let mut t = Vec::new();
        for i in 0..n {
            let mut count = 0;
            for j in 0..m {
                if rng.bernoulli(p) {
                    t.push((i, j, 1.0));
                    count += 1;
                }
            }
            if count == 0 {
                t.push((i, rng.below(m), 1.0));
            }
        }
        Self { a: Csc::from_triplets(n, m, t), d }
    }
}

impl GradientCode for RbgcCode {
    fn name(&self) -> String {
        format!("rbgc(d={})", self.d)
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

/// Batch raptor code (Wang, Liu & Shroff [9]), simulated substrate: the
/// blocks are grouped into batches of size `batch`; each machine
/// samples a degree from a truncated robust-soliton-style distribution
/// and stores that many uniformly random batches (the sum of their
/// blocks). We realize the *assignment* matrix (which batches each
/// machine touches); the decoder is the generic LSQR optimal decoder,
/// matching the "optimal decoding" row for BRC in Table I.
///
/// Substitution note (DESIGN.md §3): [9] decodes with a peeling decoder
/// over XOR-like batch sums; its decoding-error *statistics* under
/// random stragglers are governed by the same A(p) pseudoinverse
/// characterization (Eq. 9), which is what we reproduce.
pub struct BrcCode {
    a: Csc,
    batch: usize,
}

impl BrcCode {
    pub fn new(n: usize, m: usize, batch: usize, rng: &mut Rng) -> Self {
        assert!(n % batch == 0, "batch must divide n");
        let n_batches = n / batch;
        // truncated soliton: P(deg=1) ~ 1/2 boosted, P(deg=k) ~ 1/(k(k-1))
        let max_deg = n_batches.min(8).max(1);
        let mut weights = vec![0.0; max_deg + 1];
        weights[1] = 0.5;
        for k in 2..=max_deg {
            weights[k] = 1.0 / (k as f64 * (k as f64 - 1.0));
        }
        let total: f64 = weights.iter().sum();
        let mut t = Vec::new();
        for j in 0..m {
            // sample degree
            let mut u = rng.f64() * total;
            let mut deg = 1;
            for k in 1..=max_deg {
                if u < weights[k] {
                    deg = k;
                    break;
                }
                u -= weights[k];
                deg = k;
            }
            let batches = rng.sample_indices(n_batches, deg);
            for b in batches {
                for blk in (b * batch)..((b + 1) * batch) {
                    t.push((blk, j, 1.0));
                }
            }
        }
        Self { a: Csc::from_triplets(n, m, t), batch }
    }
}

impl GradientCode for BrcCode {
    fn name(&self) -> String {
        format!("brc(batch={})", self.batch)
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

/// Pairwise-balanced scheme of Bitar, Wootters & El Rouayheb [5]: each
/// block is stored on d machines chosen uniformly at random without
/// replacement (decoded with fixed coefficients 1/(d(1-p))).
pub struct PairwiseBalancedCode {
    a: Csc,
    d: usize,
}

impl PairwiseBalancedCode {
    pub fn new(n: usize, m: usize, d: usize, rng: &mut Rng) -> Self {
        assert!(d <= m);
        let mut t = Vec::with_capacity(n * d);
        for i in 0..n {
            for j in rng.sample_indices(m, d) {
                t.push((i, j, 1.0));
            }
        }
        Self { a: Csc::from_triplets(n, m, t), d }
    }
}

impl GradientCode for PairwiseBalancedCode {
    fn name(&self) -> String {
        format!("pairwise(d={})", self.d)
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbgc_every_block_replicated() {
        let mut rng = Rng::new(0);
        let c = RbgcCode::new(50, 50, 4, &mut rng);
        // no empty rows after regularization
        let row_counts = c.assignment().mul_vec(&vec![1.0; 50]);
        assert!(row_counts.iter().all(|&r| r >= 1.0));
        // replication near d
        let rep = c.replication();
        assert!((rep - 4.0).abs() < 1.5, "rep={rep}");
    }

    #[test]
    fn brc_batches_are_contiguous_and_whole() {
        let mut rng = Rng::new(1);
        let batch = 4;
        let c = BrcCode::new(32, 40, batch, &mut rng);
        // each machine's blocks come in whole batches
        for j in 0..40 {
            let (rows, _) = c.assignment().col(j);
            assert!(rows.len() % batch == 0, "machine {j} has partial batch");
            for chunk in rows.chunks(batch) {
                assert_eq!(chunk[0] % batch, 0);
                for (off, &r) in chunk.iter().enumerate() {
                    assert_eq!(r, chunk[0] + off);
                }
            }
        }
    }

    #[test]
    fn pairwise_exact_row_replication() {
        let mut rng = Rng::new(2);
        let c = PairwiseBalancedCode::new(30, 20, 5, &mut rng);
        let row_counts = c.assignment().mul_vec(&vec![1.0; 20]);
        assert!(row_counts.iter().all(|&r| (r - 5.0).abs() < 1e-12));
        assert!((c.replication() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let c1 = RbgcCode::new(20, 20, 3, &mut Rng::new(7));
        let c2 = RbgcCode::new(20, 20, 3, &mut Rng::new(7));
        assert_eq!(c1.assignment().rowidx, c2.assignment().rowidx);
    }
}
