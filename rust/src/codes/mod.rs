//! Gradient-coding assignment schemes: the paper's graph codes plus
//! every baseline it compares against (Table I).
//!
//! | scheme | source | decoding |
//! |---|---|---|
//! | [`GraphCode`] | this paper (Def. II.2) | linear-time optimal |
//! | [`FrcCode`] | Tandon et al. [4] | closed-form optimal |
//! | [`ExpanderAdjacencyCode`] | Raviv et al. [6] | fixed / LSQR optimal |
//! | [`BibdCode`] | Kadhe et al. [7] | fixed (= optimal, their Thm) |
//! | [`RbgcCode`] | Charles et al. [8] | LSQR optimal |
//! | [`BrcCode`] | Wang et al. [9] | LSQR optimal |
//! | [`PairwiseBalancedCode`] | Bitar et al. [5] | fixed |
//! | [`UncodedCode`] | baseline | ignore stragglers |
//!
//! All schemes expose their n x m block-to-machine assignment matrix as
//! a sparse [`Csc`]; scheme-specific structure (the graph, the FRC
//! groups) is kept alongside for the specialized decoders.

pub mod bibd;
pub mod debias;
pub mod frc;
pub mod random_codes;
pub mod zoo;

pub use bibd::BibdCode;
pub use debias::debias;
pub use frc::FrcCode;
pub use random_codes::{BrcCode, PairwiseBalancedCode, RbgcCode};

use crate::graphs::Graph;
use crate::sparse::Csc;

/// Common interface every assignment scheme implements.
pub trait GradientCode {
    /// Human-readable scheme name (used in bench tables).
    fn name(&self) -> String;
    /// The n x m assignment matrix A (blocks x machines).
    fn assignment(&self) -> &Csc;
    /// Number of data blocks n.
    fn n_blocks(&self) -> usize {
        self.assignment().rows
    }
    /// Number of machines m.
    fn n_machines(&self) -> usize {
        self.assignment().cols
    }
    /// Replication factor d (Definition I.1, block granularity).
    fn replication(&self) -> f64 {
        self.assignment().replication_factor()
    }
}

/// The paper's construction: machines are edges of a graph on the data
/// blocks (Definition II.2). Prefer expanders — random regular graphs
/// (regime 1) or LPS Ramanujan graphs (regime 2).
pub struct GraphCode {
    pub graph: Graph,
    a: Csc,
    label: String,
}

impl GraphCode {
    pub fn new(label: impl Into<String>, graph: Graph) -> Self {
        let a = graph.assignment_matrix();
        Self { graph, a, label: label.into() }
    }

    /// The paper's regime-1 assignment A_1: random d-regular graph.
    pub fn random_regular(n: usize, d: usize, rng: &mut crate::prng::Rng) -> Self {
        let g = crate::graphs::random_regular_graph(n, d, rng);
        Self::new(format!("graph-rr(n={n},d={d})"), g)
    }

    /// The paper's regime-2 assignment A_2: LPS Ramanujan graph.
    pub fn lps(p: u64, q: u64) -> Self {
        let g = crate::graphs::lps_graph(p, q);
        Self::new(format!("graph-lps({p},{q})"), g)
    }
}

impl GradientCode for GraphCode {
    fn name(&self) -> String {
        self.label.clone()
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

/// Trivial 1-replication baseline: block i lives only on machine i.
pub struct UncodedCode {
    a: Csc,
}

impl UncodedCode {
    pub fn new(n: usize) -> Self {
        let t = (0..n).map(|i| (i, i, 1.0)).collect();
        Self { a: Csc::from_triplets(n, n, t) }
    }
}

impl GradientCode for UncodedCode {
    fn name(&self) -> String {
        "uncoded".to_string()
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

/// Raviv et al. [6]: the assignment matrix is the *adjacency matrix* of
/// a d-regular graph on m = n vertices — machine j holds the blocks of
/// its d neighbors (contrast Remark II.3: blocks are vertices here too,
/// but machines are vertices rather than edges).
pub struct ExpanderAdjacencyCode {
    pub graph: Graph,
    a: Csc,
}

impl ExpanderAdjacencyCode {
    pub fn new(graph: Graph) -> Self {
        let n = graph.n;
        let mut t = Vec::with_capacity(2 * graph.m());
        for &(u, v) in &graph.edges {
            // block u on machine v and block v on machine u
            t.push((u, v, 1.0));
            t.push((v, u, 1.0));
        }
        let a = Csc::from_triplets(n, n, t);
        Self { graph, a }
    }

    pub fn random_regular(n: usize, d: usize, rng: &mut crate::prng::Rng) -> Self {
        Self::new(crate::graphs::random_regular_graph(n, d, rng))
    }
}

impl GradientCode for ExpanderAdjacencyCode {
    fn name(&self) -> String {
        format!(
            "expander-adj(n={},d={})",
            self.graph.n,
            self.graph.is_regular().unwrap_or(0)
        )
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn graph_code_shape_matches_paper_regime1() {
        let mut rng = Rng::new(0);
        let c = GraphCode::random_regular(16, 3, &mut rng);
        assert_eq!(c.n_blocks(), 16);
        assert_eq!(c.n_machines(), 24);
        assert!((c.replication() - 3.0).abs() < 1e-12);
        // every machine holds exactly 2 blocks
        assert_eq!(c.assignment().max_col_nnz(), 2);
    }

    #[test]
    fn uncoded_is_identity() {
        let c = UncodedCode::new(5);
        assert_eq!(c.replication(), 1.0);
        let d = c.assignment().to_dense();
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(d[(i, j)], if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn expander_adjacency_regular_rows_and_cols() {
        let mut rng = Rng::new(1);
        let c = ExpanderAdjacencyCode::random_regular(24, 3, &mut rng);
        assert_eq!(c.n_blocks(), 24);
        assert_eq!(c.n_machines(), 24);
        assert!((c.replication() - 3.0).abs() < 1e-12);
        assert_eq!(c.assignment().max_col_nnz(), 3);
        // machine j must NOT hold its own block (no self-loops)
        let dense = c.assignment().to_dense();
        for j in 0..24 {
            assert_eq!(dense[(j, j)], 0.0);
        }
    }
}
