//! Fractional repetition code of Tandon et al. [4].
//!
//! Machines are partitioned into m/d groups of d; data blocks are
//! partitioned into m/d groups of n/(m/d); every machine in group g
//! holds *all* blocks of block-group g. Under random stragglers with
//! optimal decoding this achieves the optimal error
//! E|alpha*-1|^2 / n = p^d / (1-p^d)-ish (exactly p^d unnormalized,
//! [8]), but adversarially it is poor: killing whole groups zeroes a p
//! fraction of all blocks (Table I, worst case p).

use super::GradientCode;
use crate::sparse::Csc;

pub struct FrcCode {
    a: Csc,
    /// group id of each machine
    pub machine_group: Vec<usize>,
    /// block ids of each group
    pub group_blocks: Vec<Vec<usize>>,
    d: usize,
}

impl FrcCode {
    /// n blocks on m machines with replication d. Requires d | m and
    /// (m/d) | n so groups are exact (the paper's experiments use
    /// n = m with d | m).
    pub fn new(n: usize, m: usize, d: usize) -> Self {
        assert!(d >= 1 && m % d == 0, "need d | m");
        let groups = m / d;
        assert!(n % groups == 0, "need (m/d) | n");
        let blocks_per_group = n / groups;
        let mut t = Vec::with_capacity(m * blocks_per_group);
        let mut machine_group = vec![0usize; m];
        let mut group_blocks = vec![Vec::with_capacity(blocks_per_group); groups];
        for g in 0..groups {
            for b in 0..blocks_per_group {
                group_blocks[g].push(g * blocks_per_group + b);
            }
            for j in 0..d {
                let machine = g * d + j;
                machine_group[machine] = g;
                for &blk in &group_blocks[g] {
                    t.push((blk, machine, 1.0));
                }
            }
        }
        Self { a: Csc::from_triplets(n, m, t), machine_group, group_blocks, d }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn n_groups(&self) -> usize {
        self.group_blocks.len()
    }

    /// Closed-form optimal decoding: for each group with >= 1 surviving
    /// machine, put total weight 1 on the survivors (alpha = 1 on its
    /// blocks); groups with no survivor get alpha = 0. Returns (w, alpha).
    pub fn optimal_decode(&self, straggler: &[bool]) -> (Vec<f64>, Vec<f64>) {
        let m = self.a.cols;
        assert_eq!(straggler.len(), m);
        let groups = self.n_groups();
        let mut survivors: Vec<Vec<usize>> = vec![Vec::new(); groups];
        for j in 0..m {
            if !straggler[j] {
                survivors[self.machine_group[j]].push(j);
            }
        }
        let mut w = vec![0.0; m];
        let mut alpha = vec![0.0; self.a.rows];
        for g in 0..groups {
            if survivors[g].is_empty() {
                continue;
            }
            let share = 1.0 / survivors[g].len() as f64;
            for &j in &survivors[g] {
                w[j] = share;
            }
            for &blk in &self.group_blocks[g] {
                alpha[blk] = 1.0;
            }
        }
        (w, alpha)
    }
}

impl GradientCode for FrcCode {
    fn name(&self) -> String {
        format!("frc(d={})", self.d)
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dist_to_ones_sq;

    #[test]
    fn shape_and_replication() {
        let c = FrcCode::new(16, 24, 3); // 8 groups of 3 machines, 2 blocks each
        assert_eq!(c.n_blocks(), 16);
        assert_eq!(c.n_machines(), 24);
        assert!((c.replication() - 3.0).abs() < 1e-12);
        assert_eq!(c.n_groups(), 8);
        // computational load: each machine holds n/groups = 2 blocks
        assert_eq!(c.assignment().max_col_nnz(), 2);
    }

    #[test]
    fn decode_all_alive_is_exact() {
        let c = FrcCode::new(12, 12, 3);
        let (w, alpha) = c.optimal_decode(&vec![false; 12]);
        assert!(alpha.iter().all(|&a| (a - 1.0).abs() < 1e-12));
        // w must reproduce alpha through A
        let aw = c.assignment().mul_vec(&w);
        assert!(dist_to_ones_sq(&aw) < 1e-20);
    }

    #[test]
    fn decode_with_dead_group() {
        let c = FrcCode::new(12, 12, 3); // 4 groups (machines 0-2, 3-5, ...)
        let mut s = vec![false; 12];
        s[3] = true;
        s[4] = true;
        s[5] = true; // kill group 1 entirely
        let (w, alpha) = c.optimal_decode(&s);
        // group 1's blocks (3,4,5) -> alpha 0, everything else 1
        for blk in 0..12 {
            let expect = if (3..6).contains(&blk) { 0.0 } else { 1.0 };
            assert_eq!(alpha[blk], expect, "blk={blk}");
        }
        // consistency: alpha == A w
        let aw = c.assignment().mul_vec(&w);
        for i in 0..12 {
            assert!((aw[i] - alpha[i]).abs() < 1e-12);
        }
        // error = 3 blocks lost
        assert!((dist_to_ones_sq(&alpha) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn partial_group_survival_still_exact() {
        let c = FrcCode::new(12, 12, 3);
        // one straggler per group -> still perfect recovery
        let mut s = vec![false; 12];
        for g in 0..4 {
            s[g * 3] = true;
        }
        let (_, alpha) = c.optimal_decode(&s);
        assert!(dist_to_ones_sq(&alpha) < 1e-20);
    }
}
