//! Balanced-incomplete-block-design codes of Kadhe et al. [7].
//!
//! We implement the projective-plane family PG(2, s): for a prime s,
//! points and lines of the projective plane of order s form a
//! (v, k, 1)-BIBD with v = s^2 + s + 1 points, v lines, k = s + 1
//! points per line, every point on s + 1 lines, every pair of points on
//! exactly 1 common line. Assignment: blocks = points, machines =
//! lines, so n = m = s^2 + s + 1 and d = ell = s + 1.
//!
//! Kadhe et al. prove that for BIBD assignments the optimal decoding
//! vector has *fixed* coefficients on the non-stragglers, so the fixed
//! decoder is exactly optimal here — a useful cross-check for our
//! generic LSQR decoder.

use super::GradientCode;
use crate::sparse::Csc;

pub struct BibdCode {
    a: Csc,
    s: usize,
}

/// Canonical form of a projective point/line (x:y:z) over F_s: scale so
/// the first non-zero coordinate is 1.
fn canon(mut v: [u64; 3], s: u64) -> [u64; 3] {
    let first = v.iter().copied().find(|&x| x != 0).expect("zero vector");
    let inv = mod_inv(first, s);
    for x in v.iter_mut() {
        *x = *x * inv % s;
    }
    v
}

fn mod_inv(a: u64, p: u64) -> u64 {
    // Fermat; p prime
    let mut r = 1u64;
    let mut b = a % p;
    let mut e = p - 2;
    while e > 0 {
        if e & 1 == 1 {
            r = r * b % p;
        }
        b = b * b % p;
        e >>= 1;
    }
    r
}

fn enumerate_points(s: u64) -> Vec<[u64; 3]> {
    let mut pts = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for x in 0..s {
        for y in 0..s {
            for z in 0..s {
                if x == 0 && y == 0 && z == 0 {
                    continue;
                }
                let c = canon([x, y, z], s);
                if seen.insert(c) {
                    pts.push(c);
                }
            }
        }
    }
    pts
}

impl BibdCode {
    /// Projective plane of prime order s.
    pub fn projective_plane(s: usize) -> Self {
        assert!(s >= 2, "order must be >= 2");
        let sq = s as u64;
        let points = enumerate_points(sq);
        let v = (s * s + s + 1) as usize;
        assert_eq!(points.len(), v, "projective plane point count");
        // lines are also projective triples (a:b:c); point (x:y:z) is on
        // line (a:b:c) iff ax + by + cz = 0 (mod s)
        let lines = points.clone();
        let mut t = Vec::with_capacity(v * (s + 1));
        for (j, l) in lines.iter().enumerate() {
            for (i, p) in points.iter().enumerate() {
                if (l[0] * p[0] + l[1] * p[1] + l[2] * p[2]) % sq == 0 {
                    t.push((i, j, 1.0));
                }
            }
        }
        let a = Csc::from_triplets(v, v, t);
        assert_eq!(a.nnz(), v * (s + 1), "incidence count");
        Self { a, s }
    }

    pub fn order(&self) -> usize {
        self.s
    }
}

impl GradientCode for BibdCode {
    fn name(&self) -> String {
        format!("bibd-pg2({})", self.s)
    }
    fn assignment(&self) -> &Csc {
        &self.a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fano_plane() {
        // s=2: the Fano plane, 7 points / 7 lines / 3 points per line
        let c = BibdCode::projective_plane(2);
        assert_eq!(c.n_blocks(), 7);
        assert_eq!(c.n_machines(), 7);
        assert!((c.replication() - 3.0).abs() < 1e-12);
        assert_eq!(c.assignment().max_col_nnz(), 3);
    }

    #[test]
    fn pairwise_balance_lambda_one() {
        // every pair of points shares exactly one line: rows of A have
        // pairwise inner product exactly 1
        let c = BibdCode::projective_plane(3); // 13 points
        let d = c.assignment().to_dense();
        for i in 0..13 {
            for j in 0..13 {
                let mut inner = 0.0;
                for l in 0..13 {
                    inner += d[(i, l)] * d[(j, l)];
                }
                if i == j {
                    assert_eq!(inner, 4.0); // point on s+1 = 4 lines
                } else {
                    assert_eq!(inner, 1.0, "pair ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn order_5_shape() {
        let c = BibdCode::projective_plane(5);
        assert_eq!(c.n_blocks(), 31);
        assert!((c.replication() - 6.0).abs() < 1e-12);
    }
}
