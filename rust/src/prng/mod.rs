//! Deterministic PRNG substrate (no `rand` crate in the offline build).
//!
//! `Xoshiro256PlusPlus` (Blackman & Vigna) seeded through `SplitMix64`,
//! plus the sampling helpers the rest of the crate needs: uniforms,
//! Gaussians, Bernoulli draws, Fisher-Yates shuffles/permutations and
//! subset sampling. Everything is reproducible from a `u64` seed, which
//! the bench harness relies on for paper-style error bars (same seeds
//! across schemes).

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

/// The deterministic per-trial substream keying shared by the sweep
/// engine and the shard layer: stream `t` of seed `s` is an [`Rng`]
/// derived only from `(s, t)` — never from generator position — so any
/// process that agrees on the seed reproduces trial `t`'s draws exactly,
/// regardless of which trials it runs or in what order.
pub fn substream(seed: u64, stream: u64) -> Rng {
    let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    Rng::new(sm.next_u64())
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 256-bit state generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from the polar method
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // avoid the all-zero state (probability 2^-256, but be exact)
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream (e.g. per worker thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard Gaussian via the Marsaglia polar method.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    pub fn gaussian_vec(&mut self, n: usize, std: f64) -> Vec<f64> {
        (0..n).map(|_| self.gaussian() * std).collect()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n (the paper's rho in Alg. 2/3).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from 0..n (Floyd's algorithm), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        let mut v: Vec<usize> = chosen.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Bernoulli(p) mask of length n: the random straggler set S.
    pub fn bernoulli_mask(&mut self, n: usize, p: f64) -> Vec<bool> {
        let mut mask = Vec::new();
        self.bernoulli_mask_into(n, p, &mut mask);
        mask
    }

    /// Allocation-free [`Rng::bernoulli_mask`]: refill a caller-owned
    /// buffer (the sweep engine's per-trial hot path). Draw-for-draw
    /// identical to the allocating variant.
    pub fn bernoulli_mask_into(&mut self, n: usize, p: f64, mask: &mut Vec<bool>) {
        mask.clear();
        mask.reserve(n);
        for _ in 0..n {
            let b = self.bernoulli(p);
            mask.push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // reference values for seed 1234567 (computed from the spec)
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn rng_deterministic_and_streams_differ() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let v1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let v2: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        assert_eq!(v1, v2);
        let mut r3 = Rng::new(43);
        let v3: Vec<u64> = (0..8).map(|_| r3.next_u64()).collect();
        assert_ne!(v1, v3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let n = 10;
        let mut counts = vec![0usize; n];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(13);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let v = r.sample_indices(50, 12);
            assert_eq!(v.len(), 12);
            assert!(v.windows(2).all(|w| w[0] < w[1]));
            assert!(v.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn bernoulli_mask_rate() {
        let mut r = Rng::new(19);
        let mask = r.bernoulli_mask(100_000, 0.2);
        let frac = mask.iter().filter(|&&b| b).count() as f64 / 100_000.0;
        assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn bernoulli_mask_into_matches_allocating() {
        let mut r1 = Rng::new(23);
        let mut r2 = Rng::new(23);
        let mut buf = vec![true; 3]; // stale contents must be discarded
        for n in [0usize, 1, 17, 100] {
            let a = r1.bernoulli_mask(n, 0.3);
            r2.bernoulli_mask_into(n, 0.3, &mut buf);
            assert_eq!(a, buf);
        }
    }

    #[test]
    fn substream_is_position_independent() {
        // keyed only by (seed, stream): same pair, same draws, always
        let mut r1 = substream(7, 3);
        let mut r2 = substream(7, 3);
        let a: Vec<u64> = (0..4).map(|_| r1.next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|_| r2.next_u64()).collect();
        assert_eq!(a, b);
        // distinct streams and distinct seeds decorrelate
        assert_ne!(substream(7, 3).next_u64(), substream(7, 4).next_u64());
        assert_ne!(substream(7, 3).next_u64(), substream(8, 3).next_u64());
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
