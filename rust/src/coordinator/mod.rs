//! Distributed coded gradient descent (the paper's Algorithm 2).
//!
//! Leader/worker architecture mirroring the paper's MPI cluster runs
//! (§VIII-B "Platform and Implementation"), with threads in place of
//! MPI ranks (DESIGN.md §3):
//!
//!  * the **leader** broadcasts the iterate, waits for the first
//!    ceil(m (1-p)) worker gradients (`MPI.Request.Waitany` semantics),
//!    marks the rest as stragglers, computes optimal (or fixed)
//!    decoding coefficients and applies the update;
//!  * each **worker** owns the data blocks its machine was assigned
//!    (for graph schemes: the two endpoint blocks of its edge), computes
//!    g_j = sum_i A_ij grad_i(theta) via its own PJRT runtime executing
//!    the AOT `worker_grad` artifact (or a native-rust fallback), and
//!    sends it back. Straggling is injected worker-side as a sleep.
//!
//! `PjRtClient` is not `Send`, so each worker thread builds its own
//! `Runtime` — exactly the per-rank process model of the MPI original.

use crate::decode::Decoder;
use crate::error::{Context, Error, Result};
#[cfg(pjrt_runtime)]
use crate::runtime::{Runtime, Tensor};
use crate::sparse::Csc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How worker gradients are computed.
#[derive(Clone, Debug)]
pub enum ComputeBackend {
    /// Execute the AOT `worker_grad_*` artifact via PJRT (the real
    /// three-layer path). `artifact` must match (blocks, b, k).
    /// Only available with the `pjrt` feature.
    #[cfg(pjrt_runtime)]
    Pjrt { artifacts_dir: String, artifact: String },
    /// Pure-rust gradient (for very large m where per-thread PJRT
    /// clients are wasteful, and for differential testing).
    Native,
}

/// Worker-side straggler injection.
#[derive(Clone, Debug)]
pub enum StragglerInjection {
    /// no injected delays: stragglers are just the slowest arrivals
    None,
    /// each worker sleeps `delay` before computing with prob. p per iter
    Random { p: f64, delay: Duration, seed: u64 },
    /// sticky stragglers (the cluster behaviour conjectured in §VIII)
    Stagnant { p: f64, churn: f64, delay: Duration, seed: u64 },
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// wait for ceil(m * wait_fraction) gradients, then decode
    pub wait_fraction: f64,
    pub backend: ComputeBackend,
    pub injection: StragglerInjection,
    pub step_size: f64,
    pub iters: usize,
    /// stop early once this wall-clock budget is exhausted (Fig. 4b
    /// reports error after a fixed time budget)
    pub max_duration: Option<Duration>,
}

/// Per-iteration record.
#[derive(Clone, Debug)]
pub struct IterStats {
    pub iter: usize,
    pub wall: Duration,
    pub stragglers: usize,
    /// which machines were cut off by the waitany threshold
    pub straggler_mask: Vec<bool>,
    pub decode_error_sq: f64,
    pub progress: f64,
}

#[derive(Debug)]
pub struct RunReport {
    pub iters: Vec<IterStats>,
    pub total: Duration,
    pub final_progress: f64,
}

enum LeaderMsg {
    Broadcast { iter: usize, theta: Arc<Vec<f32>> },
    Shutdown,
}

struct GradMsg {
    worker: usize,
    iter: usize,
    grad: Vec<f32>,
}

/// Worker-private state.
struct WorkerData {
    /// flattened (blocks, b, k) f32
    x: Vec<f32>,
    /// flattened (blocks, b) f32
    y: Vec<f32>,
    blocks: usize,
    b: usize,
    k: usize,
}

impl WorkerData {
    /// Native gradient: g = sum over blocks of X_i^T (X_i theta - y_i).
    fn native_grad(&self, theta: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; self.k];
        for blk in 0..self.blocks {
            for r in 0..self.b {
                let row = &self.x[(blk * self.b + r) * self.k..(blk * self.b + r + 1) * self.k];
                let mut resid = -self.y[blk * self.b + r];
                for c in 0..self.k {
                    resid += row[c] * theta[c];
                }
                for c in 0..self.k {
                    g[c] += resid * row[c];
                }
            }
        }
        g
    }
}

fn should_straggle(injection: &StragglerInjection, worker: usize, iter: usize) -> Option<Duration> {
    match injection {
        StragglerInjection::None => None,
        StragglerInjection::Random { p, delay, seed } => {
            let mut rng = crate::prng::Rng::new(
                seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ (iter as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9),
            );
            rng.bernoulli(*p).then_some(*delay)
        }
        StragglerInjection::Stagnant { p, churn, delay, seed } => {
            // sticky: status changes only on churn events; derive the
            // status from the most recent churn epoch for this worker
            let mut epoch = iter;
            loop {
                let mut rng = crate::prng::Rng::new(
                    seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ (epoch as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
                );
                if epoch == 0 || rng.bernoulli(*churn) {
                    return rng.bernoulli(*p).then_some(*delay);
                }
                epoch -= 1;
            }
        }
    }
}

/// The distributed cluster: leader + m worker threads.
pub struct Cluster {
    pub m: usize,
    pub k: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
    to_workers: Vec<mpsc::Sender<LeaderMsg>>,
    from_workers: mpsc::Receiver<GradMsg>,
    ready_workers: Arc<AtomicUsize>,
}

impl Cluster {
    /// Distribute data according to the assignment matrix: machine j
    /// receives the blocks in column j of A. All columns must hold the
    /// same number of blocks when using the PJRT backend (the artifact
    /// shape is static).
    pub fn spawn(
        a: &Csc,
        data: &crate::data::LstsqData,
        cfg: &ClusterConfig,
    ) -> Result<Self> {
        let m = a.cols;
        let k = data.k;
        let b = data.b;
        let (to_leader, from_workers) = mpsc::channel::<GradMsg>();
        let mut to_workers = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        let ready_workers = Arc::new(AtomicUsize::new(0));

        for j in 0..m {
            let (tx, rx) = mpsc::channel::<LeaderMsg>();
            to_workers.push(tx);
            let (blocks, _) = a.col(j);
            let blocks = blocks.to_vec();
            let (x, y) = data.machine_f32_buffers(&blocks);
            let wd = WorkerData { x, y, blocks: blocks.len(), b, k };
            let backend = cfg.backend.clone();
            let injection = cfg.injection.clone();
            let sender = to_leader.clone();
            let ready = ready_workers.clone();
            handles.push(std::thread::spawn(move || {
                worker_main(j, wd, backend, injection, rx, sender, ready);
            }));
        }
        Ok(Self { m, k, handles, to_workers, from_workers, ready_workers })
    }

    /// Block until every worker finished its (possibly PJRT-compiling)
    /// startup, so timing starts at steady state like the paper ("we
    /// start timing once the data has been loaded").
    pub fn wait_ready(&self, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        while self.ready_workers.load(Ordering::SeqCst) < self.m {
            if t0.elapsed() > timeout {
                return Err(Error::msg(format!(
                    "only {}/{} workers ready after {timeout:?}",
                    self.ready_workers.load(Ordering::SeqCst),
                    self.m
                )));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }

    /// Run coded GD: broadcast, gather the fastest, decode, update.
    /// `progress` maps theta to the reported metric (e.g. |theta-theta*|^2).
    pub fn run(
        &mut self,
        cfg: &ClusterConfig,
        decoder: &dyn Decoder,
        theta0: &[f64],
        progress: impl Fn(&[f64]) -> f64,
    ) -> Result<RunReport> {
        let m = self.m;
        let k = self.k;
        let wait_for = ((m as f64) * cfg.wait_fraction).ceil() as usize;
        let wait_for = wait_for.clamp(1, m);
        let mut theta: Vec<f64> = theta0.to_vec();
        let mut iters = Vec::with_capacity(cfg.iters);
        let t_start = Instant::now();

        for it in 0..cfg.iters {
            if let Some(budget) = cfg.max_duration {
                if t_start.elapsed() > budget {
                    break;
                }
            }
            let t_iter = Instant::now();
            let theta32: Arc<Vec<f32>> = Arc::new(theta.iter().map(|&v| v as f32).collect());
            for tx in &self.to_workers {
                let _ = tx.send(LeaderMsg::Broadcast { iter: it, theta: theta32.clone() });
            }
            // gather the first `wait_for` gradients of THIS iteration
            let mut grads: Vec<Option<Vec<f32>>> = vec![None; m];
            let mut got = 0usize;
            while got < wait_for {
                let msg = self
                    .from_workers
                    .recv_timeout(Duration::from_secs(120))
                    .context("leader timed out waiting for workers")?;
                if msg.iter != it {
                    continue; // stale gradient from a slow worker
                }
                if grads[msg.worker].is_none() {
                    grads[msg.worker] = Some(msg.grad);
                    got += 1;
                }
            }
            let straggler_mask: Vec<bool> = grads.iter().map(|g| g.is_none()).collect();
            let n_straggle = straggler_mask.iter().filter(|&&s| s).count();
            let dec = decoder.decode(&straggler_mask);
            // update: theta -= gamma * sum_j w_j g_j
            let mut update = vec![0.0f64; k];
            for j in 0..m {
                if let Some(g) = &grads[j] {
                    let wj = dec.w[j];
                    if wj != 0.0 {
                        for c in 0..k {
                            update[c] += wj * g[c] as f64;
                        }
                    }
                }
            }
            for c in 0..k {
                theta[c] -= cfg.step_size * update[c];
            }
            iters.push(IterStats {
                iter: it,
                wall: t_iter.elapsed(),
                stragglers: n_straggle,
                straggler_mask,
                decode_error_sq: dec.error_sq(),
                progress: progress(&theta),
            });
        }
        let final_progress = progress(&theta);
        Ok(RunReport { iters, total: t_start.elapsed(), final_progress })
    }

    pub fn shutdown(mut self) {
        for tx in &self.to_workers {
            let _ = tx.send(LeaderMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(
    id: usize,
    data: WorkerData,
    backend: ComputeBackend,
    injection: StragglerInjection,
    rx: mpsc::Receiver<LeaderMsg>,
    tx: mpsc::Sender<GradMsg>,
    ready: Arc<AtomicUsize>,
) {
    // per-thread PJRT runtime (PjRtClient is not Send)
    #[cfg(pjrt_runtime)]
    let pjrt: Option<(Runtime, String)> = match &backend {
        ComputeBackend::Pjrt { artifacts_dir, artifact } => {
            let rt = Runtime::open(artifacts_dir)
                .unwrap_or_else(|e| panic!("worker {id}: runtime open failed: {e}"));
            // compile eagerly so startup cost is excluded from timing
            rt.load(artifact)
                .unwrap_or_else(|e| panic!("worker {id}: artifact load failed: {e}"));
            Some((rt, artifact.clone()))
        }
        ComputeBackend::Native => None,
    };
    #[cfg(not(pjrt_runtime))]
    let _ = &backend;
    ready.fetch_add(1, Ordering::SeqCst);

    loop {
        // block for the next message, then drain to the latest
        // broadcast (a worker that slept through iterations drops the
        // stale ones, like a real slow rank would)
        let mut msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        loop {
            match rx.try_recv() {
                Ok(newer) => msg = newer,
                Err(_) => break,
            }
        }
        match msg {
            LeaderMsg::Shutdown => return,
            LeaderMsg::Broadcast { iter, theta } => {
                if let Some(delay) = should_straggle(&injection, id, iter) {
                    std::thread::sleep(delay);
                }
                #[cfg(pjrt_runtime)]
                let grad = match &pjrt {
                    Some((rt, artifact)) => {
                        let inputs = [
                            Tensor::f32(&[data.k], theta.as_ref().clone()),
                            Tensor::f32(&[data.blocks, data.b, data.k], data.x.clone()),
                            Tensor::f32(&[data.blocks, data.b], data.y.clone()),
                        ];
                        let out = rt
                            .run(artifact, &inputs)
                            .unwrap_or_else(|e| panic!("worker {id}: exec failed: {e}"));
                        // output: per-block grads (blocks, k); machine
                        // message is their sum g_j = sum_i A_ij grad_i
                        let per_block = out.into_iter().next().unwrap().into_f32().unwrap();
                        let mut g = vec![0.0f32; data.k];
                        for blk in 0..data.blocks {
                            for c in 0..data.k {
                                g[c] += per_block[blk * data.k + c];
                            }
                        }
                        g
                    }
                    None => data.native_grad(&theta),
                };
                #[cfg(not(pjrt_runtime))]
                let grad = data.native_grad(&theta);
                let _ = tx.send(GradMsg { worker: id, iter, grad });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, GraphCode};
    use crate::decode::OptimalGraphDecoder;
    use crate::prng::Rng;

    /// Native-backend cluster converges like batch GD when no one
    /// straggles (PJRT-backend integration lives in rust/tests/).
    #[test]
    fn native_cluster_converges_without_stragglers() {
        let mut rng = Rng::new(0);
        let code = GraphCode::random_regular(8, 3, &mut rng); // m = 12
        let data = crate::data::LstsqData::generate(32, 6, 8, 0.2, &mut rng);
        let cfg = ClusterConfig {
            wait_fraction: 1.0,
            backend: ComputeBackend::Native,
            injection: StragglerInjection::None,
            step_size: 0.05,
            iters: 60,
            max_duration: None,
        };
        let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg).unwrap();
        cluster.wait_ready(Duration::from_secs(10)).unwrap();
        let dec = OptimalGraphDecoder::new(&code.graph);
        let report = cluster
            .run(&cfg, &dec, &vec![0.0; 6], |t| data.dist_to_opt(t))
            .unwrap();
        cluster.shutdown();
        let e0 = data.dist_to_opt(&vec![0.0; 6]);
        assert!(
            report.final_progress < e0 * 1e-2,
            "no convergence: {e0} -> {}",
            report.final_progress
        );
        assert!(report.iters.iter().all(|s| s.stragglers == 0));
        assert!(report.iters.iter().all(|s| s.decode_error_sq < 1e-18));
    }

    #[test]
    fn native_cluster_with_waitany_stragglers() {
        let mut rng = Rng::new(1);
        let code = GraphCode::random_regular(8, 3, &mut rng);
        let data = crate::data::LstsqData::generate(32, 6, 8, 0.2, &mut rng);
        let cfg = ClusterConfig {
            wait_fraction: 0.75, // wait for 9 of 12
            backend: ComputeBackend::Native,
            injection: StragglerInjection::Random {
                p: 0.25,
                delay: Duration::from_millis(30),
                seed: 7,
            },
            step_size: 0.04,
            iters: 40,
            max_duration: None,
        };
        let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg).unwrap();
        cluster.wait_ready(Duration::from_secs(10)).unwrap();
        let dec = OptimalGraphDecoder::new(&code.graph);
        let report = cluster
            .run(&cfg, &dec, &vec![0.0; 6], |t| data.dist_to_opt(t))
            .unwrap();
        cluster.shutdown();
        // exactly m - ceil(0.75 m) = 3 stragglers per iteration
        assert!(report.iters.iter().all(|s| s.stragglers == 3), "{:?}",
                report.iters.iter().map(|s| s.stragglers).collect::<Vec<_>>());
        let e0 = data.dist_to_opt(&vec![0.0; 6]);
        assert!(report.final_progress < e0 * 0.2, "{} -> {}", e0, report.final_progress);
    }

    #[test]
    fn native_grad_matches_block_grads() {
        let mut rng = Rng::new(2);
        let data = crate::data::LstsqData::generate(12, 4, 6, 0.1, &mut rng);
        let (x, y) = data.machine_f32_buffers(&[1, 4]);
        let wd = WorkerData { x, y, blocks: 2, b: 2, k: 4 };
        let theta: Vec<f64> = rng.gaussian_vec(4, 1.0);
        let theta32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
        let g = wd.native_grad(&theta32);
        let full = data.block_grads(&theta);
        for c in 0..4 {
            let want = full[(1, c)] + full[(4, c)];
            assert!((g[c] as f64 - want).abs() < 1e-3, "{} vs {}", g[c], want);
        }
    }
}
