//! Configuration substrate: a TOML-subset file format + typed settings.
//!
//! Experiment configs (examples/, benches/) are flat `key = value`
//! files with optional `[section]` headers; the CLI can override any
//! key with `--set section.key=value`. No serde/toml crates in the
//! offline build, so the parser lives here.

pub mod json;

use std::collections::BTreeMap;
use std::fmt;

/// Flat settings map with dotted keys ("section.key").
#[derive(Clone, Debug, Default)]
pub struct Settings {
    map: BTreeMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct ConfigError {
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

fn err(msg: impl Into<String>) -> ConfigError {
    ConfigError { msg: msg.into() }
}

impl Settings {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse TOML-subset text: comments (#), [sections], key = value.
    /// Values: bare numbers/bools, "quoted strings", [a, b, c] arrays
    /// (stored as comma-joined strings).
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut s = Self::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err(format!("line {}: expected key = value", lineno + 1)))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            s.map.insert(key, parse_value(v.trim())?);
        }
        Ok(s)
    }

    pub fn load(path: &std::path::Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Apply a CLI override "key=value".
    pub fn set_override(&mut self, kv: &str) -> Result<(), ConfigError> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| err(format!("override '{kv}' must be key=value")))?;
        self.map.insert(k.trim().to_string(), parse_value(v.trim())?);
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get_str(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get_str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get_str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get_str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get_str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated f64 list (from `[a, b]` arrays or "a,b" strings).
    pub fn f64_list(&self, key: &str) -> Option<Vec<f64>> {
        let s = self.get_str(key)?;
        s.split(',')
            .map(|x| x.trim().parse::<f64>().ok())
            .collect::<Option<Vec<_>>>()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn parse_value(v: &str) -> Result<String, ConfigError> {
    if let Some(inner) = v.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(inner.to_string());
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let parts: Vec<String> = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<_, _>>()?;
        return Ok(parts.join(","));
    }
    if v.is_empty() {
        return Err(err("empty value"));
    }
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let s = Settings::parse(
            "top = 1\n[run]\np = 0.2 # straggler rate\nname = \"fig4\"\n\
             iters = 50\nflag = true\nps = [0.05, 0.1]\n",
        )
        .unwrap();
        assert_eq!(s.usize_or("top", 0), 1);
        assert_eq!(s.f64_or("run.p", 0.0), 0.2);
        assert_eq!(s.str_or("run.name", ""), "fig4");
        assert!(s.bool_or("run.flag", false));
        assert_eq!(s.f64_list("run.ps").unwrap(), vec![0.05, 0.1]);
    }

    #[test]
    fn overrides_and_defaults() {
        let mut s = Settings::parse("[a]\nx = 1\n").unwrap();
        s.set_override("a.x=5").unwrap();
        assert_eq!(s.usize_or("a.x", 0), 5);
        assert_eq!(s.usize_or("a.missing", 7), 7);
        assert!(s.set_override("noequals").is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Settings::parse("just a line\n").is_err());
        assert!(Settings::parse("k =\n").is_err());
    }
}
