//! Minimal JSON parser (offline build: no serde).
//!
//! Parses the artifact MANIFEST.json emitted by `python/compile/aot.py`
//! plus any experiment config files. Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (not needed here).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            (x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64).then_some(x as usize)
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {} }"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").is_some());
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"α\"").unwrap(), Json::Str("α".into()));
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{"artifacts": [{"name": "x", "file": "x.hlo.txt",
                     "inputs": [{"shape": [2,3], "dtype": "f32"}],
                     "outputs": [{"shape": [3], "dtype": "f32"}]}],
                    "transformer": null}"#;
        let v = Json::parse(s).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("x"));
        let inp = arts[0].get("inputs").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = inp[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 3]);
    }
}
