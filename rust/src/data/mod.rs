//! Synthetic workloads: the paper's least-squares problem (§VIII-B) and
//! a token corpus for the transformer end-to-end example.

use crate::linalg::{chol::lstsq_normal, dist2_sq, Mat};
use crate::prng::Rng;

/// The paper's regression data: X (N x k) with i.i.d. rows from
/// N(0, I/k), theta ~ N(0, I), Y = X theta + Z with Z ~ sigma N(0, I).
/// Rows are pre-split into n equal blocks of b = N/n rows, matching the
/// blocks-as-vertices assignment.
pub struct LstsqData {
    pub x: Mat,
    pub y: Vec<f64>,
    pub n_blocks: usize,
    /// rows per block
    pub b: usize,
    pub k: usize,
    /// exact minimizer (X^T X)^{-1} X^T Y
    pub theta_star: Vec<f64>,
    /// the planted parameter (before noise)
    pub theta_true: Vec<f64>,
}

impl LstsqData {
    pub fn generate(n_points: usize, k: usize, n_blocks: usize, sigma: f64, rng: &mut Rng) -> Self {
        assert!(n_points % n_blocks == 0, "blocks must divide N");
        let scale = 1.0 / (k as f64).sqrt();
        let mut x = Mat::zeros(n_points, k);
        for v in x.data.iter_mut() {
            *v = rng.gaussian() * scale;
        }
        let theta_true = rng.gaussian_vec(k, 1.0);
        let mut y = x.mul_vec(&theta_true);
        for v in y.iter_mut() {
            *v += sigma * rng.gaussian();
        }
        let theta_star = lstsq_normal(&x, &y, 0.0).expect("X^T X should be PD for N > k");
        Self { x, y, n_blocks, b: n_points / n_blocks, k, theta_star, theta_true }
    }

    pub fn n_points(&self) -> usize {
        self.x.rows
    }

    /// Same data points, different blocking (e.g. the expander code of
    /// [6] uses one block per machine while the graph scheme uses
    /// n = 2m/d blocks). Rows are contiguous so only metadata changes.
    pub fn reblock(&self, n_blocks: usize) -> Self {
        assert!(self.n_points() % n_blocks == 0, "blocks must divide N");
        Self {
            x: self.x.clone(),
            y: self.y.clone(),
            n_blocks,
            b: self.n_points() / n_blocks,
            k: self.k,
            theta_star: self.theta_star.clone(),
            theta_true: self.theta_true.clone(),
        }
    }

    /// Zero-copy view of block `blk`'s rows, packed row-major (b x k) —
    /// blocks are contiguous row ranges, so the slice feeds the
    /// [`crate::linalg::syrk_into`] Gram kernel directly.
    pub fn block_x(&self, blk: usize) -> &[f64] {
        let row0 = blk * self.b;
        &self.x.data[row0 * self.k..(row0 + self.b) * self.k]
    }

    /// Zero-copy view of block `blk`'s targets (length b).
    pub fn block_y(&self, blk: usize) -> &[f64] {
        let row0 = blk * self.b;
        &self.y[row0..row0 + self.b]
    }

    /// Per-block gradients G (n x k): G[i] = X_i^T (X_i theta - y_i),
    /// the same quantity the Pallas `block_grad` kernel computes.
    /// Allocating wrapper around [`LstsqData::block_grads_into`].
    pub fn block_grads(&self, theta: &[f64]) -> Mat {
        let mut g = Mat::zeros(self.n_blocks, self.k);
        self.block_grads_into(theta, &mut g);
        g
    }

    /// Allocation-free streaming gradient: one pass over the data
    /// matrix, writing into a caller-owned `g` (reset to shape, so a
    /// warm scratch never reallocates). Accumulation order is identical
    /// to the historical allocating path — results are bit-identical.
    pub fn block_grads_into(&self, theta: &[f64], g: &mut Mat) {
        self.block_grads_into_backend(theta, g, crate::linalg::LinalgBackend::Exact);
    }

    /// [`LstsqData::block_grads_into`] on an explicit linalg tier: the
    /// per-row residual dot dispatches through `backend` (`Exact` is
    /// bit-identical to the historical path); the rank-1 `axpy` update
    /// is element-wise — no reduction order — and stays shared.
    pub fn block_grads_into_backend(
        &self,
        theta: &[f64],
        g: &mut Mat,
        backend: crate::linalg::LinalgBackend,
    ) {
        g.reset(self.n_blocks, self.k);
        for blk in 0..self.n_blocks {
            let row0 = blk * self.b;
            for r in 0..self.b {
                let xr = self.x.row(row0 + r);
                let resid = backend.dot(xr, theta) - self.y[row0 + r];
                crate::linalg::axpy(resid, xr, g.row_mut(blk));
            }
        }
    }

    /// Full-batch gradient = sum of block gradients.
    pub fn full_grad(&self, theta: &[f64]) -> Vec<f64> {
        let g = self.block_grads(theta);
        let mut out = vec![0.0; self.k];
        for i in 0..self.n_blocks {
            crate::linalg::axpy(1.0, g.row(i), &mut out);
        }
        out
    }

    /// |theta - theta*|^2, the convergence metric in Figures 4 and 5.
    pub fn dist_to_opt(&self, theta: &[f64]) -> f64 {
        dist2_sq(theta, &self.theta_star)
    }

    /// Objective |X theta - y|^2 (for loss curves).
    pub fn loss(&self, theta: &[f64]) -> f64 {
        let r = self.x.mul_vec(theta);
        r.iter().zip(&self.y).map(|(a, b)| (a - b) * (a - b)).sum()
    }

    /// Block-data buffers in the layout the AOT artifacts expect:
    /// X as (n, b, k) f32 row-major and y as (n, b) f32.
    pub fn to_f32_buffers(&self) -> (Vec<f32>, Vec<f32>) {
        let xb: Vec<f32> = self.x.data.iter().map(|&v| v as f32).collect();
        let yb: Vec<f32> = self.y.iter().map(|&v| v as f32).collect();
        (xb, yb)
    }

    /// The f32 buffers for the blocks a machine holds (graph schemes: 2).
    pub fn machine_f32_buffers(&self, blocks: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut xb = Vec::with_capacity(blocks.len() * self.b * self.k);
        let mut yb = Vec::with_capacity(blocks.len() * self.b);
        for &blk in blocks {
            let row0 = blk * self.b;
            for r in 0..self.b {
                xb.extend(self.x.row(row0 + r).iter().map(|&v| v as f32));
                yb.push(self.y[row0 + r] as f32);
            }
        }
        (xb, yb)
    }
}

/// Synthetic byte-level corpus for the transformer E2E example: a
/// pattern bank with Zipf-ish reuse plus noise, so the LM has real
/// structure to learn (loss decreases measurably in a few hundred
/// steps). Emits (n_blocks, batch, seq+1) i32 token blocks.
pub struct TokenCorpus {
    pub tokens: Vec<i32>,
    pub vocab: usize,
}

impl TokenCorpus {
    pub fn generate(len: usize, vocab: usize, rng: &mut Rng) -> Self {
        assert!(vocab >= 16);
        // pattern bank: 16 motifs of length 8-24 over a skewed alphabet
        let motifs: Vec<Vec<i32>> = (0..16)
            .map(|_| {
                let l = 8 + rng.below(17);
                (0..l)
                    .map(|_| {
                        // Zipf-ish: favor low token ids
                        let r = rng.f64();
                        ((r * r * (vocab as f64 - 1.0)) as i32).min(vocab as i32 - 1)
                    })
                    .collect()
            })
            .collect();
        let mut tokens = Vec::with_capacity(len);
        while tokens.len() < len {
            if rng.bernoulli(0.85) {
                // Zipf over motifs: motif 0 most common
                let idx = {
                    let r = rng.f64();
                    ((r * r * 16.0) as usize).min(15)
                };
                tokens.extend_from_slice(&motifs[idx]);
            } else {
                // noise run
                for _ in 0..4 {
                    tokens.push(rng.below(vocab) as i32);
                }
            }
        }
        tokens.truncate(len);
        Self { tokens, vocab }
    }

    /// Slice into (n_blocks, batch, seq+1) i32 blocks, row-major.
    pub fn blocks(
        &self,
        n_blocks: usize,
        batch: usize,
        seq_plus1: usize,
        rng: &mut Rng,
    ) -> Vec<i32> {
        let per_seq = seq_plus1;
        let total = n_blocks * batch * per_seq;
        let mut out = Vec::with_capacity(total);
        for _ in 0..(n_blocks * batch) {
            let start = rng.below(self.tokens.len() - per_seq);
            out.extend_from_slice(&self.tokens[start..start + per_seq]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LstsqData {
        let mut rng = Rng::new(0);
        LstsqData::generate(40, 5, 8, 0.5, &mut rng)
    }

    #[test]
    fn shapes_and_splits() {
        let d = small();
        assert_eq!(d.n_points(), 40);
        assert_eq!(d.b, 5);
        assert_eq!(d.block_grads(&vec![0.0; 5]).rows, 8);
    }

    #[test]
    fn theta_star_is_stationary() {
        let d = small();
        let g = d.full_grad(&d.theta_star);
        assert!(crate::linalg::norm2(&g) < 1e-8, "grad at opt = {:?}", g);
    }

    #[test]
    fn block_grads_sum_to_full() {
        let d = small();
        let mut rng = Rng::new(1);
        let theta = rng.gaussian_vec(5, 1.0);
        let g = d.block_grads(&theta);
        let mut sum = vec![0.0; 5];
        for i in 0..8 {
            crate::linalg::axpy(1.0, g.row(i), &mut sum);
        }
        let full = d.full_grad(&theta);
        assert!(dist2_sq(&sum, &full) < 1e-18);
    }

    #[test]
    fn gradient_descent_decreases_distance() {
        let d = small();
        let mut theta = vec![0.0; 5];
        let e0 = d.dist_to_opt(&theta);
        for _ in 0..200 {
            let g = d.full_grad(&theta);
            crate::linalg::axpy(-0.05, &g, &mut theta);
        }
        assert!(d.dist_to_opt(&theta) < e0 * 1e-3);
    }

    #[test]
    fn block_views_match_indexing() {
        let d = small();
        for blk in 0..8 {
            let bx = d.block_x(blk);
            let by = d.block_y(blk);
            assert_eq!(bx.len(), 5 * 5);
            assert_eq!(by.len(), 5);
            for r in 0..5 {
                assert_eq!(&bx[r * 5..(r + 1) * 5], d.x.row(blk * 5 + r));
                assert_eq!(by[r], d.y[blk * 5 + r]);
            }
        }
    }

    #[test]
    fn block_grads_into_reuses_scratch_bitwise() {
        let d = small();
        let mut rng = Rng::new(9);
        let mut g = Mat::zeros(0, 0);
        for _ in 0..3 {
            let theta = rng.gaussian_vec(5, 1.0);
            let want = d.block_grads(&theta);
            d.block_grads_into(&theta, &mut g); // dirty scratch reused
            assert_eq!(g.data.len(), want.data.len());
            for (a, b) in g.data.iter().zip(&want.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn f32_buffers_layout() {
        let d = small();
        let (xb, yb) = d.to_f32_buffers();
        assert_eq!(xb.len(), 40 * 5);
        assert_eq!(yb.len(), 40);
        assert!((xb[0] as f64 - d.x[(0, 0)]).abs() < 1e-6);
        let (mx, my) = d.machine_f32_buffers(&[2, 5]);
        assert_eq!(mx.len(), 2 * 5 * 5);
        assert_eq!(my.len(), 2 * 5);
        assert!((mx[0] as f64 - d.x[(10, 0)]).abs() < 1e-6);
    }

    #[test]
    fn corpus_tokens_in_range_and_structured() {
        let mut rng = Rng::new(2);
        let c = TokenCorpus::generate(10_000, 256, &mut rng);
        assert_eq!(c.tokens.len(), 10_000);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        // structure: unigram distribution must be skewed (motifs reuse
        // low ids), so low half should dominate
        let low = c.tokens.iter().filter(|&&t| t < 128).count();
        assert!(low > 6_000, "low={low}");
        let blocks = c.blocks(4, 2, 65, &mut rng);
        assert_eq!(blocks.len(), 4 * 2 * 65);
    }
}
