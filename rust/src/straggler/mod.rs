//! Straggler models: random (Definition I.2) and adversarial
//! (Definition I.3), plus the stagnant model conjectured in §VIII.
//!
//! `sample` returns a boolean mask over machines: true = straggles.

use crate::codes::{FrcCode, GradientCode};
use crate::graphs::Graph;
use crate::prng::Rng;

pub trait StragglerModel {
    fn sample(&mut self, m: usize) -> Vec<bool>;

    /// Allocation-free [`StragglerModel::sample`]: refill a caller-owned
    /// mask (the GD hot loop's per-iteration path). Implementations must
    /// be draw-for-draw identical to `sample`; the default allocates.
    fn sample_into(&mut self, m: usize, mask: &mut Vec<bool>) {
        *mask = self.sample(m);
    }

    fn name(&self) -> String;
}

/// Each machine straggles independently with probability p (the
/// random-straggler model of Definition I.2 and Algorithm 2).
pub struct BernoulliStragglers {
    pub p: f64,
    pub rng: Rng,
}

impl BernoulliStragglers {
    pub fn new(p: f64, seed: u64) -> Self {
        Self { p, rng: Rng::new(seed) }
    }
}

impl StragglerModel for BernoulliStragglers {
    fn sample(&mut self, m: usize) -> Vec<bool> {
        self.rng.bernoulli_mask(m, self.p)
    }
    fn sample_into(&mut self, m: usize, mask: &mut Vec<bool>) {
        self.rng.bernoulli_mask_into(m, self.p, mask);
    }
    fn name(&self) -> String {
        format!("bernoulli(p={})", self.p)
    }
}

/// Exactly floor(p m) uniformly-random stragglers — the MPI-Waitany
/// semantics of the paper's cluster experiments ("the PS waits for the
/// first ceil(m (1-p)) processors").
pub struct FixedFractionStragglers {
    pub p: f64,
    pub rng: Rng,
}

impl FixedFractionStragglers {
    pub fn new(p: f64, seed: u64) -> Self {
        Self { p, rng: Rng::new(seed) }
    }
}

impl StragglerModel for FixedFractionStragglers {
    fn sample(&mut self, m: usize) -> Vec<bool> {
        let k = (self.p * m as f64).floor() as usize;
        let mut mask = vec![false; m];
        for j in self.rng.sample_indices(m, k) {
            mask[j] = true;
        }
        mask
    }
    fn name(&self) -> String {
        format!("fixed-frac(p={})", self.p)
    }
}

/// Stagnant stragglers: "which machines are straggling tends to stay
/// stagnant throughout a run" (§VIII conjecture for why the graph
/// scheme beats the FRC on a real cluster). Each round, every machine
/// keeps its state with probability 1 - churn, else resamples.
pub struct StagnantStragglers {
    pub p: f64,
    pub churn: f64,
    rng: Rng,
    current: Vec<bool>,
}

impl StagnantStragglers {
    pub fn new(p: f64, churn: f64, seed: u64) -> Self {
        Self { p, churn, rng: Rng::new(seed), current: Vec::new() }
    }
}

impl StagnantStragglers {
    /// Advance the sticky state one round (shared by both sample paths).
    fn advance(&mut self, m: usize) {
        if self.current.len() != m {
            self.current = self.rng.bernoulli_mask(m, self.p);
        } else {
            for j in 0..m {
                if self.rng.bernoulli(self.churn) {
                    self.current[j] = self.rng.bernoulli(self.p);
                }
            }
        }
    }
}

impl StragglerModel for StagnantStragglers {
    fn sample(&mut self, m: usize) -> Vec<bool> {
        self.advance(m);
        self.current.clone()
    }
    fn sample_into(&mut self, m: usize, mask: &mut Vec<bool>) {
        self.advance(m);
        mask.clear();
        mask.extend_from_slice(&self.current);
    }
    fn name(&self) -> String {
        format!("stagnant(p={},churn={})", self.p, self.churn)
    }
}

/// A committed adversarial straggler pattern, replayed every round
/// (Definition I.3 once the adversary has spent its budget). The
/// decoding error — the quantity a greedy decode adversary maximizes —
/// depends only on the mask, never on the iterate or the block
/// shuffle, so a per-iteration greedy adversary loses nothing by
/// committing once per run; this model is how the `adv-gd` sweep
/// kernel replays that committed mask through [`StragglerModel`]
/// consumers like [`crate::gd::SimulatedGcod`]. Borrows the mask, so
/// per-trial construction allocates nothing.
pub struct FixedMaskStragglers<'a> {
    mask: &'a [bool],
}

impl<'a> FixedMaskStragglers<'a> {
    pub fn new(mask: &'a [bool]) -> Self {
        Self { mask }
    }
}

impl StragglerModel for FixedMaskStragglers<'_> {
    fn sample(&mut self, m: usize) -> Vec<bool> {
        assert_eq!(m, self.mask.len(), "fixed mask covers {} machines, asked for {m}",
                   self.mask.len());
        self.mask.to_vec()
    }
    fn sample_into(&mut self, m: usize, out: &mut Vec<bool>) {
        assert_eq!(m, self.mask.len(), "fixed mask covers {} machines, asked for {m}",
                   self.mask.len());
        out.clear();
        out.extend_from_slice(self.mask);
    }
    fn name(&self) -> String {
        format!("fixed-mask({} stragglers)", self.mask.iter().filter(|&&s| s).count())
    }
}

/// Adapter from a [`StragglerModel`] to per-worker startup *delays*,
/// for the dispatch layer's straggler simulation: each call samples a
/// mask over the worker pool and maps straggling workers to `delay`,
/// healthy ones to zero. Any model plugs in — Bernoulli for the
/// paper's random model, [`StagnantStragglers`] for sticky slow hosts.
pub struct DelaySampler<M: StragglerModel> {
    model: M,
    delay: std::time::Duration,
}

impl<M: StragglerModel> DelaySampler<M> {
    pub fn new(model: M, delay: std::time::Duration) -> Self {
        Self { model, delay }
    }

    /// Delay for each of `m` workers this round.
    pub fn sample_delays(&mut self, m: usize) -> Vec<std::time::Duration> {
        self.model
            .sample(m)
            .into_iter()
            .map(|s| if s { self.delay } else { std::time::Duration::ZERO })
            .collect()
    }

    pub fn name(&self) -> String {
        format!("delay({}, {:?})", self.model.name(), self.delay)
    }
}

// ---------------------------------------------------------------------
// Adversarial attacks (Definition I.3): budget floor(p m) machines
// ---------------------------------------------------------------------

/// Attack on graph schemes (Remark V.4): isolate whole data blocks by
/// straggling every machine (edge) incident to chosen vertices. Each
/// isolated block forces alpha_i = 0, costing (1-0)^2 = 1 — so with
/// budget pm and degree d the adversary zeroes ~pm/d blocks, giving
/// |alpha*-1|^2/n >= p/2 for graph schemes (nd = 2m). Vertices are
/// chosen greedily to avoid wasting budget on shared edges.
pub fn graph_isolation_attack(g: &Graph, budget: usize) -> Vec<bool> {
    let m = g.m();
    let mut straggle = vec![false; m];
    let mut spent = 0usize;
    let mut killed = vec![false; g.n];
    // greedy: prefer vertices whose remaining (un-straggled) degree is
    // smallest so each isolation costs the least budget
    loop {
        let mut best: Option<(usize, usize)> = None; // (cost, vertex)
        for v in 0..g.n {
            if killed[v] {
                continue;
            }
            let cost = g.adj[v].iter().filter(|&&(_, e)| !straggle[e]).count();
            if best.map(|(c, _)| cost < c).unwrap_or(true) {
                best = Some((cost, v));
            }
        }
        match best {
            Some((cost, v)) if spent + cost <= budget => {
                for &(_, e) in &g.adj[v] {
                    if !straggle[e] {
                        straggle[e] = true;
                        spent += 1;
                    }
                }
                killed[v] = true;
            }
            _ => break,
        }
    }
    // spend any leftover budget on arbitrary extra edges (they can only
    // help the adversary)
    for e in 0..m {
        if spent >= budget {
            break;
        }
        if !straggle[e] {
            straggle[e] = true;
            spent += 1;
        }
    }
    straggle
}

/// Attack on the FRC (the paper's motivation for Question 1): kill
/// whole machine-groups. Each dead group zeroes all its blocks, so a
/// budget of pm machines zeroes a p fraction of all data blocks —
/// error/n = p, versus ~p/2 for graph schemes (Table I).
pub fn frc_group_attack(code: &FrcCode, budget: usize) -> Vec<bool> {
    let m = code.assignment().cols;
    let d = code.d();
    let mut straggle = vec![false; m];
    let mut spent = 0;
    for g in 0..code.n_groups() {
        if spent + d > budget {
            break;
        }
        for j in 0..m {
            if code.machine_group[j] == g {
                straggle[j] = true;
                spent += 1;
            }
        }
    }
    // leftovers on arbitrary machines
    for j in 0..m {
        if spent >= budget {
            break;
        }
        if !straggle[j] {
            straggle[j] = true;
            spent += 1;
        }
    }
    straggle
}

/// Generic greedy attack for arbitrary codes: repeatedly straggle the
/// machine whose removal most increases the optimal decoding error
/// (evaluated with the provided decoder), breaking zero-gain ties by
/// attacking the block with the fewest surviving replicas (greedy
/// decoding error alone is myopic: on an expander no single extra
/// straggler moves alpha* until a block is fully isolated).
/// O(budget * m * decode-cost) — use on small m only. For larger m, use
/// [`greedy_decode_attack_on`], which fans the candidate evaluation
/// across a [`TrialEngine`].
pub fn greedy_decode_attack<D: crate::decode::Decoder + ?Sized>(
    decoder: &D,
    a: &crate::sparse::Csc,
    budget: usize,
) -> Vec<bool> {
    greedy_decode_attack_trace(decoder, a, budget).0
}

/// [`greedy_decode_attack`] plus its per-step error trace: element `s`
/// of the returned vector is the decoding error |alpha* - 1|^2 after
/// the first `s + 1` greedily-chosen stragglers. Because the greedy
/// masks are nested (each step adds one machine), one pass to budget
/// `B` yields the whole attack-vs-budget curve — the trace is a pure
/// function of `(decoder, a)`, which is what lets the shard layer slice
/// the budget axis across processes bit-exactly.
pub fn greedy_decode_attack_trace<D: crate::decode::Decoder + ?Sized>(
    decoder: &D,
    a: &crate::sparse::Csc,
    budget: usize,
) -> (Vec<bool>, Vec<f64>) {
    let m = a.cols;
    let mut straggle = vec![false; m];
    let mut out = crate::decode::Decoding::empty();
    let mut trace = Vec::with_capacity(budget);
    // surviving replica count per block
    let mut replicas = a.mul_vec(&vec![1.0; m]);
    for _ in 0..budget {
        let mut best: Option<(f64, f64, usize)> = None; // (err, tie-score, machine)
        for j in 0..m {
            if straggle[j] {
                continue;
            }
            straggle[j] = true;
            decoder.decode_into(&straggle, &mut out);
            let err = out.error_sq();
            straggle[j] = false;
            let tie = isolation_tie_score(a, j, &replicas);
            if better_candidate(best, err, tie) {
                best = Some((err, tie, j));
            }
        }
        match best {
            Some((err, _, j)) => {
                straggle[j] = true;
                let (rows, _) = a.col(j);
                for &i in rows {
                    replicas[i] -= 1.0;
                }
                trace.push(err);
            }
            None => {
                // budget exceeds m: every machine already straggles and
                // the trace is flat from here — decode the saturated
                // mask once and pad
                decoder.decode_into(&straggle, &mut out);
                let saturated = out.error_sq();
                trace.resize(budget, saturated);
                break;
            }
        }
    }
    (straggle, trace)
}

/// Engine-parallel greedy attack: each greedy step evaluates all
/// candidate machines as engine trials (every worker owns a decoder
/// from `make_decoder` plus its own copy of the current mask), then
/// the argmax folds over candidates in machine order.
///
/// Candidates are dealt one per chunk (a fresh decoder per candidate),
/// so the evaluation parallelizes even when m is smaller than the
/// engine's default chunk, and the selected mask is independent of
/// both the thread count and the engine's configured chunk size. For
/// *stateless* decoders (the graph and FRC decoders) it is additionally
/// identical to [`greedy_decode_attack`]'s. For the warm-started
/// [`crate::decode::GenericOptimalDecoder`] the two searches see
/// LSQR-tolerance-level differences in candidate errors (serial threads
/// one warm decoder through the whole search), so near-exact ties may
/// resolve to a different — equally greedy — machine.
pub fn greedy_decode_attack_on<D, F>(
    engine: &crate::sweep::TrialEngine,
    make_decoder: F,
    a: &crate::sparse::Csc,
    budget: usize,
) -> Vec<bool>
where
    D: crate::decode::Decoder,
    F: Fn(usize) -> D + Sync,
{
    let m = a.cols;
    let mut straggle = vec![false; m];
    let mut replicas = a.mul_vec(&vec![1.0; m]);
    // one candidate per chunk: parallelizes for small m and decouples
    // the result from the engine's chunk configuration
    let engine = engine.clone().with_chunk(1);
    for _ in 0..budget {
        let errs: Vec<Option<f64>> = engine.run_map(
            m,
            |chunk| {
                (make_decoder(chunk), crate::decode::Decoding::empty(), straggle.clone())
            },
            |(dec, out, mask), j, _rng| {
                if mask[j] {
                    return None;
                }
                mask[j] = true;
                dec.decode_into(mask, out);
                mask[j] = false;
                Some(out.error_sq())
            },
        );
        let mut best: Option<(f64, f64, usize)> = None;
        for (j, err) in errs.into_iter().enumerate() {
            let Some(err) = err else { continue };
            let tie = isolation_tie_score(a, j, &replicas);
            if better_candidate(best, err, tie) {
                best = Some((err, tie, j));
            }
        }
        if let Some((_, _, j)) = best {
            straggle[j] = true;
            let (rows, _) = a.col(j);
            for &i in rows {
                replicas[i] -= 1.0;
            }
        }
    }
    straggle
}

/// Tie score: how close machine j's blocks are to isolation.
#[inline]
fn isolation_tie_score(a: &crate::sparse::Csc, j: usize, replicas: &[f64]) -> f64 {
    let (rows, _) = a.col(j);
    rows.iter().map(|&i| 1.0 / replicas[i].max(1.0)).fold(0.0f64, f64::max)
}

/// Shared greedy comparison so the serial and engine attacks pick
/// identical machines.
#[inline]
fn better_candidate(best: Option<(f64, f64, usize)>, err: f64, tie: f64) -> bool {
    match best {
        None => true,
        Some((be, bt, _)) => err > be + 1e-15 || ((err - be).abs() <= 1e-15 && tie > bt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, GraphCode};
    use crate::decode::{Decoder, OptimalGraphDecoder};
    use crate::graphs::random_regular_graph;

    #[test]
    fn bernoulli_rate() {
        let mut s = BernoulliStragglers::new(0.3, 1);
        let mask = s.sample(50_000);
        let frac = mask.iter().filter(|&&b| b).count() as f64 / 50_000.0;
        assert!((frac - 0.3).abs() < 0.02);
    }

    #[test]
    fn fixed_fraction_exact_count() {
        let mut s = FixedFractionStragglers::new(0.25, 2);
        for _ in 0..10 {
            let mask = s.sample(24);
            assert_eq!(mask.iter().filter(|&&b| b).count(), 6);
        }
    }

    #[test]
    fn delay_sampler_maps_mask_to_delays() {
        let delay = std::time::Duration::from_millis(80);
        let mut s = DelaySampler::new(BernoulliStragglers::new(0.5, 9), delay);
        let d = s.sample_delays(1000);
        assert!(d.iter().all(|&x| x.is_zero() || x == delay));
        let slow = d.iter().filter(|x| !x.is_zero()).count();
        assert!((300..700).contains(&slow), "slow={slow}");
        assert!(s.name().contains("bernoulli"));
    }

    #[test]
    fn fixed_mask_replays_exactly() {
        let mask = vec![true, false, true, false, false];
        let mut s = FixedMaskStragglers::new(&mask);
        assert_eq!(s.sample(5), mask);
        let mut out = vec![false; 99]; // stale, wrong-sized buffer
        s.sample_into(5, &mut out);
        assert_eq!(out, mask);
        // repeated draws never drift
        assert_eq!(s.sample(5), mask);
        assert!(s.name().contains("2 stragglers"), "{}", s.name());
    }

    #[test]
    fn stagnant_is_sticky() {
        let mut s = StagnantStragglers::new(0.3, 0.05, 3);
        let a = s.sample(100);
        let b = s.sample(100);
        let changed = a.iter().zip(&b).filter(|(x, y)| x != y).count();
        assert!(changed < 20, "changed={changed}");
    }

    #[test]
    fn isolation_attack_respects_budget_and_hurts() {
        let mut rng = crate::prng::Rng::new(7);
        let g = random_regular_graph(20, 4, &mut rng);
        let budget = 8; // p = 0.2 of m = 40
        let mask = graph_isolation_attack(&g, budget);
        assert_eq!(mask.iter().filter(|&&b| b).count(), budget);
        let code = GraphCode::new("t", g);
        let err = OptimalGraphDecoder::new(&code.graph).decode(&mask).error_sq();
        // should isolate budget/d = 2 vertices -> error >= 2
        assert!(err >= 2.0 - 1e-9, "err={err}");
    }

    #[test]
    fn frc_attack_zeroes_p_fraction() {
        let code = crate::codes::FrcCode::new(16, 24, 3);
        let budget = 6; // two whole groups
        let mask = frc_group_attack(&code, budget);
        assert_eq!(mask.iter().filter(|&&b| b).count(), budget);
        let d = crate::decode::FrcOptimalDecoder::new(&code).decode(&mask);
        // 2 groups x 2 blocks per group zeroed
        assert!((d.error_sq() - 4.0).abs() < 1e-12, "err={}", d.error_sq());
    }

    #[test]
    fn greedy_trace_is_monotone_and_matches_mask() {
        let mut rng = crate::prng::Rng::new(12);
        let g = random_regular_graph(10, 3, &mut rng);
        let code = GraphCode::new("t", g);
        let dec = OptimalGraphDecoder::new(&code.graph);
        let budget = 6;
        let (mask, trace) = greedy_decode_attack_trace(&dec, code.assignment(), budget);
        assert_eq!(trace.len(), budget);
        assert_eq!(mask.iter().filter(|&&s| s).count(), budget);
        // the last trace entry is the final mask's error, bit for bit
        let fin = dec.decode(&mask).error_sq();
        assert_eq!(trace[budget - 1].to_bits(), fin.to_bits());
        // adding stragglers can only increase the optimal error
        for w in trace.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "trace decreased: {trace:?}");
        }
        // a prefix run reproduces the prefix of the trace (nestedness)
        let (_, short) = greedy_decode_attack_trace(&dec, code.assignment(), 3);
        for i in 0..3 {
            assert_eq!(short[i].to_bits(), trace[i].to_bits(), "step {i}");
        }
    }

    #[test]
    fn greedy_attack_at_least_matches_random() {
        let mut rng = crate::prng::Rng::new(8);
        let g = random_regular_graph(12, 3, &mut rng);
        let code = GraphCode::new("t", g);
        let dec = OptimalGraphDecoder::new(&code.graph);
        let budget = 4;
        let adv = greedy_decode_attack(&dec, code.assignment(), budget);
        let adv_err = dec.decode(&adv).error_sq();
        // greedy is myopic, so compare against the *mean* random error:
        // a real adversary must do at least as well as an average draw
        let mut sum = 0.0f64;
        let trials = 50;
        for _ in 0..trials {
            let mut mask = vec![false; code.n_machines()];
            for j in rng.sample_indices(code.n_machines(), budget) {
                mask[j] = true;
            }
            sum += dec.decode(&mask).error_sq();
        }
        let mean_random = sum / trials as f64;
        assert!(adv_err >= mean_random - 1e-9, "adv={adv_err} mean rnd={mean_random}");
    }
}
