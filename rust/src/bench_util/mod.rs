//! Benchmark harness substrate (no criterion in the offline build).
//!
//! Each `rust/benches/bench_*.rs` target uses `harness = false` and
//! drives this runner: warmup, timed iterations, mean/std/min reporting,
//! plus the experiment-table helpers the paper-figure benches share.

use crate::metrics::{Stats, Stopwatch};
use std::time::Duration;

/// Timing result for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<48} {:>12}/iter (±{}, min {}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.min),
            self.iters
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured calls, then measured calls
/// until `budget` elapses or `max_iters` is reached (min 3 iters).
pub fn bench<F: FnMut()>(name: &str, warmup: u64, budget: Duration, max_iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    let total = Stopwatch::new();
    let mut iters = 0u64;
    while iters < 3 || (total.elapsed() < budget && iters < max_iters) {
        let sw = Stopwatch::new();
        f();
        stats.push(sw.elapsed_secs());
        iters += 1;
    }
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(stats.mean()),
        std: Duration::from_secs_f64(stats.std()),
        min: Duration::from_secs_f64(stats.min()),
    };
    res.report();
    res
}

/// Convenience: quick bench with defaults (3 warmup, 2s budget).
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, Duration::from_secs(2), 10_000, f)
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Parse simple `--flag value` args for bench binaries (they receive
/// `--bench` from cargo, which is ignored).
pub struct BenchArgs {
    args: Vec<String>,
}

impl Default for BenchArgs {
    fn default() -> Self {
        Self::from_env()
    }
}

impl BenchArgs {
    pub fn from_env() -> Self {
        Self { args: std::env::args().skip(1).filter(|a| a != "--bench").collect() }
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == flag)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    pub fn f64_or(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    /// `--quick` trims the sweep for CI-style runs.
    pub fn quick(&self) -> bool {
        self.has("--quick")
    }
}

/// The straggler-fraction grid every paper figure sweeps.
pub const P_GRID: [f64; 6] = [0.05, 0.10, 0.15, 0.20, 0.25, 0.30];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0u64;
        let r = bench("noop", 1, Duration::from_millis(1), 5, || {
            count += 1;
        });
        assert!(r.iters >= 3);
        assert_eq!(count, r.iters + 1); // + warmup
        assert!(r.min <= r.mean);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn bench_args_parse() {
        let a = BenchArgs { args: vec!["--p".into(), "0.2".into(), "--quick".into()] };
        assert_eq!(a.f64_or("--p", 0.0), 0.2);
        assert!(a.quick());
        assert_eq!(a.usize_or("--runs", 50), 50);
    }
}
