//! Benchmark harness substrate (no criterion in the offline build).
//!
//! Each `rust/benches/bench_*.rs` target uses `harness = false` and
//! drives this runner: warmup, timed iterations, mean/std/min reporting,
//! plus the experiment-table helpers the paper-figure benches share.

use crate::metrics::{Stats, Stopwatch};
use std::time::Duration;

/// Timing result for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<48} {:>12}/iter (±{}, min {}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.min),
            self.iters
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured calls, then measured calls
/// until `budget` elapses or `max_iters` is reached (min 3 iters).
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: u64,
    budget: Duration,
    max_iters: u64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    let total = Stopwatch::new();
    let mut iters = 0u64;
    while iters < 3 || (total.elapsed() < budget && iters < max_iters) {
        let sw = Stopwatch::new();
        f();
        stats.push(sw.elapsed_secs());
        iters += 1;
    }
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(stats.mean()),
        std: Duration::from_secs_f64(stats.std()),
        min: Duration::from_secs_f64(stats.min()),
    };
    res.report();
    res
}

/// Convenience: quick bench with defaults (3 warmup, 2s budget).
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, Duration::from_secs(2), 10_000, f)
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Parse simple `--flag value` args for bench binaries (they receive
/// `--bench` from cargo, which is ignored).
pub struct BenchArgs {
    args: Vec<String>,
}

impl Default for BenchArgs {
    fn default() -> Self {
        Self::from_env()
    }
}

impl BenchArgs {
    pub fn from_env() -> Self {
        Self { args: std::env::args().skip(1).filter(|a| a != "--bench").collect() }
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == flag)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    pub fn f64_or(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    /// `--quick` trims the sweep for CI-style runs.
    pub fn quick(&self) -> bool {
        self.has("--quick")
    }

    /// `--threads N` with an all-cores default — the worker-count knob
    /// every engine-backed bench shares.
    pub fn threads(&self) -> usize {
        self.usize_or(
            "--threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Comma-separated usize list (e.g. `--workers 2,4,8`); falls back
    /// to `default` when the flag is absent or any element fails to
    /// parse.
    pub fn usize_list_or(&self, flag: &str, default: &[usize]) -> Vec<usize> {
        match self.get(flag) {
            Some(s) => {
                let parsed: Option<Vec<usize>> =
                    s.split(',').map(|x| x.trim().parse::<usize>().ok()).collect();
                match parsed {
                    Some(v) if !v.is_empty() => v,
                    _ => default.to_vec(),
                }
            }
            None => default.to_vec(),
        }
    }
}

/// The straggler-fraction grid every paper figure sweeps.
pub const P_GRID: [f64; 6] = [0.05, 0.10, 0.15, 0.20, 0.25, 0.30];

// ---------------------------------------------------------------------
// Machine-readable bench output (BENCH_*.json trajectories)
// ---------------------------------------------------------------------

/// One record in a bench JSON report.
#[derive(Clone, Debug)]
pub struct JsonRecord {
    pub name: String,
    /// mean wall time per unit of work, nanoseconds
    pub mean_ns: f64,
    /// mean_ns divided by the problem's edge/machine count (None when
    /// the record has no natural per-edge normalization)
    pub ns_per_edge: Option<f64>,
    /// worker threads used (1 = serial)
    pub threads: usize,
    pub iters: u64,
}

/// Collects [`JsonRecord`]s and writes a `BENCH_*.json` file so bench
/// trajectories can be diffed across commits. No serde in the offline
/// build — the writer emits the fixed schema by hand.
#[derive(Debug)]
pub struct JsonReport {
    bench: String,
    records: Vec<JsonRecord>,
}

/// Schema version stamped into `BENCH_*.json` reports.
pub const BENCH_SCHEMA: u64 = 1;

/// Escape a string for embedding in the hand-rolled JSON writers (this
/// report and the sweep shard manifests — no serde in the offline
/// build).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

/// Bit-exact f64 serialization for cross-process merging: the IEEE-754
/// bit pattern as 16 lowercase hex digits. Decimal JSON numbers are kept
/// alongside for humans, but merges parse this field so every value
/// round-trips exactly — including -0.0, infinities and NaNs, which
/// decimal JSON cannot carry.
pub fn f64_to_hex_bits(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Inverse of [`f64_to_hex_bits`].
pub fn f64_from_hex_bits(s: &str) -> Option<f64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

/// Human-readable decimal for manifest JSON: Rust's shortest
/// round-trip `Display` for finite values, `null` otherwise (JSON has
/// no inf/nan literals; the `_bits` sibling is authoritative).
pub fn json_f64_display(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

impl JsonReport {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, rec: JsonRecord) {
        self.records.push(rec);
    }

    /// Convenience: record a [`BenchResult`] directly.
    pub fn push_result(&mut self, r: &BenchResult, edges: Option<usize>, threads: usize) {
        let mean_ns = r.mean.as_nanos() as f64;
        self.push(JsonRecord {
            name: r.name.clone(),
            mean_ns,
            ns_per_edge: edges.map(|e| mean_ns / e.max(1) as f64),
            threads,
            iters: r.iters,
        });
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        out.push_str(&format!("  \"schema\": {BENCH_SCHEMA},\n"));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            let per_edge = match r.ns_per_edge {
                Some(v) => json_f64(v),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_ns\": {}, \"ns_per_edge\": {}, \
                 \"threads\": {}, \"iters\": {}}}{}\n",
                json_escape(&r.name),
                json_f64(r.mean_ns),
                per_edge,
                r.threads,
                r.iters,
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write to `path` (e.g. `BENCH_decode.json`).
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0u64;
        let r = bench("noop", 1, Duration::from_millis(1), 5, || {
            count += 1;
        });
        assert!(r.iters >= 3);
        assert_eq!(count, r.iters + 1); // + warmup
        assert!(r.min <= r.mean);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn bench_args_parse() {
        let a = BenchArgs { args: vec!["--p".into(), "0.2".into(), "--quick".into()] };
        assert_eq!(a.f64_or("--p", 0.0), 0.2);
        assert!(a.quick());
        assert_eq!(a.usize_or("--runs", 50), 50);
    }

    #[test]
    fn usize_list_parsing() {
        let a = BenchArgs { args: vec!["--workers".into(), "2, 4,8".into()] };
        assert_eq!(a.usize_list_or("--workers", &[1]), vec![2, 4, 8]);
        assert_eq!(a.usize_list_or("--missing", &[3, 5]), vec![3, 5]);
        let bad = BenchArgs { args: vec!["--workers".into(), "2,x".into()] };
        assert_eq!(bad.usize_list_or("--workers", &[1]), vec![1]);
    }

    #[test]
    fn f64_hex_bits_round_trip() {
        for x in [0.0, -0.0, 1.5, -3.25e-30, f64::INFINITY, f64::NEG_INFINITY, f64::MIN_POSITIVE] {
            let s = f64_to_hex_bits(x);
            assert_eq!(s.len(), 16);
            assert_eq!(f64_from_hex_bits(&s).unwrap().to_bits(), x.to_bits(), "{x}");
        }
        // NaN payload preserved bit-for-bit
        let nan = f64::from_bits(0x7ff8_0000_0000_1234);
        assert_eq!(f64_from_hex_bits(&f64_to_hex_bits(nan)).unwrap().to_bits(), nan.to_bits());
        assert!(f64_from_hex_bits("xyz").is_none());
        assert!(f64_from_hex_bits("0").is_none());
    }

    #[test]
    fn json_f64_display_round_trips_and_guards() {
        for x in [0.1, -7.25, 1e300, 4.9e-324] {
            let s = json_f64_display(x);
            assert_eq!(s.parse::<f64>().unwrap().to_bits(), x.to_bits(), "{x}");
        }
        assert_eq!(json_f64_display(f64::NAN), "null");
        assert_eq!(json_f64_display(f64::INFINITY), "null");
    }

    #[test]
    fn json_report_round_trip() {
        let mut rep = JsonReport::new("bench_decode_perf");
        rep.push(JsonRecord {
            name: "graph-decode \"n=32768\"".into(),
            mean_ns: 1234.5678,
            ns_per_edge: Some(0.0125),
            threads: 8,
            iters: 100,
        });
        rep.push(JsonRecord {
            name: "lsqr".into(),
            mean_ns: 9.0,
            ns_per_edge: None,
            threads: 1,
            iters: 3,
        });
        let s = rep.render();
        assert!(s.contains("\"bench\": \"bench_decode_perf\""));
        assert!(s.contains("\\\"n=32768\\\"")); // quotes escaped
        assert!(s.contains("\"threads\": 8"));
        assert!(s.contains("\"ns_per_edge\": null"));
        // exactly one comma between the two records
        assert_eq!(s.matches("},\n").count(), 1);
        // writes to disk
        let dir = std::env::temp_dir().join("gcod_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        rep.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), s);
    }
}
