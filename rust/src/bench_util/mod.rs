//! Benchmark harness substrate (no criterion in the offline build).
//!
//! Each `rust/benches/bench_*.rs` target uses `harness = false` and
//! drives this runner: warmup, timed iterations, mean/std/min reporting,
//! plus the experiment-table helpers the paper-figure benches share.
//!
//! Since schema 2 every record carries its per-iteration samples and a
//! deterministic percentile-bootstrap confidence interval for the mean
//! ([`bootstrap_ci_mean`]), and regression gating is statistical:
//! [`compare_against_baseline`] fails a record only when its interval
//! and the tracked baseline's interval are disjoint with the new mean
//! on the slow side — a single noisy run can widen an interval, but it
//! cannot fake a separation.

use crate::config::json::Json;
use crate::metrics::{Stats, Stopwatch};
use std::time::Duration;

/// Timing result for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
    /// Per-iteration wall times, seconds, in measurement order.
    pub samples: Vec<f64>,
    /// Bootstrap CI bounds for the mean (see [`bootstrap_ci_mean`]).
    pub ci_lo: Duration,
    pub ci_hi: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<48} {:>12}/iter (±{}, min {}, ci [{}, {}], n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.min),
            fmt_dur(self.ci_lo),
            fmt_dur(self.ci_hi),
            self.iters
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured calls, then measured calls
/// until `budget` elapses or `max_iters` is reached (min 3 iters).
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: u64,
    budget: Duration,
    max_iters: u64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    let mut samples = Vec::new();
    let total = Stopwatch::new();
    let mut iters = 0u64;
    while iters < 3 || (total.elapsed() < budget && iters < max_iters) {
        let sw = Stopwatch::new();
        f();
        let s = sw.elapsed_secs();
        stats.push(s);
        samples.push(s);
        iters += 1;
    }
    let (ci_lo, ci_hi) = bootstrap_ci_mean(&samples, BOOT_RESAMPLES, BOOT_ALPHA, BOOT_SEED);
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(stats.mean()),
        std: Duration::from_secs_f64(stats.std()),
        min: Duration::from_secs_f64(stats.min()),
        samples,
        ci_lo: Duration::from_secs_f64(ci_lo.max(0.0)),
        ci_hi: Duration::from_secs_f64(ci_hi.max(0.0)),
    };
    res.report();
    res
}

// ---------------------------------------------------------------------
// Bootstrap confidence intervals (deterministic, crate-local PRNG)
// ---------------------------------------------------------------------

/// Resampling policy shared by every bench target, so the gate always
/// compares like with like: 400 resamples is enough for stable 2.5/97.5
/// percentiles of the mean, and the fixed seed makes re-rendering the
/// same samples give the same interval bit for bit.
pub const BOOT_RESAMPLES: usize = 400;
/// Two-sided miscoverage: 0.05 -> a 95% interval.
pub const BOOT_ALPHA: f64 = 0.05;
/// Fixed bootstrap seed (the interval is a pure function of samples).
pub const BOOT_SEED: u64 = 0x9c0d_bea7;
/// Bootstrap cost is `resamples * n`; longer runs are strided down to
/// this many samples first. A subsample's interval is still a valid
/// interval for the mean, just slightly wider.
pub const MAX_CI_SAMPLES: usize = 2048;

/// Percentile-bootstrap confidence interval for the mean of `samples`.
///
/// Deterministic: resampling indices come from the crate's own seeded
/// generator, never the OS, so identical samples always produce
/// identical bounds. Degenerate inputs collapse gracefully: an empty
/// slice gives a NaN interval (rendered `null`, ignored by the gate)
/// and a single sample gives a point interval.
pub fn bootstrap_ci_mean(samples: &[f64], resamples: usize, alpha: f64, seed: u64) -> (f64, f64) {
    match samples.len() {
        0 => return (f64::NAN, f64::NAN),
        1 => return (samples[0], samples[0]),
        _ => {}
    }
    let s = stride_cap(samples, MAX_CI_SAMPLES);
    let n = s.len();
    let mut rng = crate::prng::Rng::new(seed);
    let mut means = Vec::with_capacity(resamples.max(2));
    for _ in 0..resamples.max(2) {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += s[rng.below(n)];
        }
        means.push(acc / n as f64);
    }
    means.sort_by(f64::total_cmp);
    let pick = |q: f64| {
        let i = (q * (means.len() - 1) as f64).round() as usize;
        means[i.min(means.len() - 1)]
    };
    (pick(alpha / 2.0), pick(1.0 - alpha / 2.0))
}

/// Even-stride subsample capping `samples` at `cap` elements
/// (deterministic; always keeps the first element).
fn stride_cap(samples: &[f64], cap: usize) -> Vec<f64> {
    if samples.len() <= cap {
        return samples.to_vec();
    }
    let step = samples.len().div_ceil(cap);
    samples.iter().step_by(step).copied().collect()
}

/// Convenience: quick bench with defaults (3 warmup, 2s budget).
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, Duration::from_secs(2), 10_000, f)
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Parse simple `--flag value` args for bench binaries (they receive
/// `--bench` from cargo, which is ignored).
pub struct BenchArgs {
    args: Vec<String>,
}

impl Default for BenchArgs {
    fn default() -> Self {
        Self::from_env()
    }
}

impl BenchArgs {
    pub fn from_env() -> Self {
        Self { args: std::env::args().skip(1).filter(|a| a != "--bench").collect() }
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == flag)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    pub fn f64_or(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    /// `--quick` trims the sweep for CI-style runs.
    pub fn quick(&self) -> bool {
        self.has("--quick")
    }

    /// `--threads N` with an all-cores default — the worker-count knob
    /// every engine-backed bench shares.
    pub fn threads(&self) -> usize {
        self.usize_or(
            "--threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Comma-separated usize list (e.g. `--workers 2,4,8`); falls back
    /// to `default` when the flag is absent or any element fails to
    /// parse.
    pub fn usize_list_or(&self, flag: &str, default: &[usize]) -> Vec<usize> {
        match self.get(flag) {
            Some(s) => {
                let parsed: Option<Vec<usize>> =
                    s.split(',').map(|x| x.trim().parse::<usize>().ok()).collect();
                match parsed {
                    Some(v) if !v.is_empty() => v,
                    _ => default.to_vec(),
                }
            }
            None => default.to_vec(),
        }
    }
}

/// The straggler-fraction grid every paper figure sweeps.
pub const P_GRID: [f64; 6] = [0.05, 0.10, 0.15, 0.20, 0.25, 0.30];

// ---------------------------------------------------------------------
// Machine-readable bench output (BENCH_*.json trajectories)
// ---------------------------------------------------------------------

/// One record in a bench JSON report.
#[derive(Clone, Debug)]
pub struct JsonRecord {
    pub name: String,
    /// mean wall time per unit of work, nanoseconds
    pub mean_ns: f64,
    /// mean_ns divided by the problem's edge/machine count (None when
    /// the record has no natural per-edge normalization)
    pub ns_per_edge: Option<f64>,
    /// worker threads used (1 = serial)
    pub threads: usize,
    pub iters: u64,
    /// Bootstrap CI bounds for `mean_ns` ([`bootstrap_ci_mean`]); NaN
    /// (rendered `null`) when the record has no samples.
    pub ci_lo_ns: f64,
    pub ci_hi_ns: f64,
    /// Strided subset of the per-iteration samples (ns), capped at
    /// [`MAX_JSON_SAMPLES`] so tracked reports stay reviewable.
    pub samples_ns: Vec<f64>,
}

/// Samples kept per record in the JSON file. The CI is computed from
/// the full run (up to [`MAX_CI_SAMPLES`]); this only bounds file size.
pub const MAX_JSON_SAMPLES: usize = 64;

/// Build a schema-2 record from per-iteration wall times in seconds.
/// This is the one place the bootstrap policy is applied, so every
/// bench target gates on the same kind of interval.
pub fn record_from_samples(
    name: &str,
    samples_secs: &[f64],
    edges: Option<usize>,
    threads: usize,
) -> JsonRecord {
    let mean_s = if samples_secs.is_empty() {
        f64::NAN
    } else {
        samples_secs.iter().sum::<f64>() / samples_secs.len() as f64
    };
    let (lo, hi) = bootstrap_ci_mean(samples_secs, BOOT_RESAMPLES, BOOT_ALPHA, BOOT_SEED);
    JsonRecord {
        name: name.to_string(),
        mean_ns: mean_s * 1e9,
        ns_per_edge: edges.map(|e| mean_s * 1e9 / e.max(1) as f64),
        threads,
        iters: samples_secs.len() as u64,
        ci_lo_ns: lo * 1e9,
        ci_hi_ns: hi * 1e9,
        samples_ns: stride_cap(samples_secs, MAX_JSON_SAMPLES).iter().map(|s| s * 1e9).collect(),
    }
}

/// Collects [`JsonRecord`]s and writes a `BENCH_*.json` file so bench
/// trajectories can be diffed across commits. No serde in the offline
/// build — the writer emits the fixed schema by hand.
#[derive(Debug)]
pub struct JsonReport {
    bench: String,
    records: Vec<JsonRecord>,
}

/// Schema version stamped into `BENCH_*.json` reports. Schema 2 added
/// per-record `ci_lo_ns`/`ci_hi_ns` bootstrap bounds and a `samples_ns`
/// array; schema-1 files still parse as baselines (their records just
/// carry no interval, so the statistical gate skips them).
pub const BENCH_SCHEMA: u64 = 2;

/// Escape a string for embedding in the hand-rolled JSON writers (this
/// report and the sweep shard manifests — no serde in the offline
/// build).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

/// Bit-exact f64 serialization for cross-process merging: the IEEE-754
/// bit pattern as 16 lowercase hex digits. Decimal JSON numbers are kept
/// alongside for humans, but merges parse this field so every value
/// round-trips exactly — including -0.0, infinities and NaNs, which
/// decimal JSON cannot carry.
pub fn f64_to_hex_bits(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Inverse of [`f64_to_hex_bits`].
pub fn f64_from_hex_bits(s: &str) -> Option<f64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

/// Human-readable decimal for manifest JSON: Rust's shortest
/// round-trip `Display` for finite values, `null` otherwise (JSON has
/// no inf/nan literals; the `_bits` sibling is authoritative).
pub fn json_f64_display(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

impl JsonReport {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, rec: JsonRecord) {
        self.records.push(rec);
    }

    /// Convenience: record a [`BenchResult`] directly (its samples
    /// drive the bootstrap interval).
    pub fn push_result(&mut self, r: &BenchResult, edges: Option<usize>, threads: usize) {
        self.push(record_from_samples(&r.name, &r.samples, edges, threads));
    }

    /// The records collected so far (the gate's input).
    pub fn records(&self) -> &[JsonRecord] {
        &self.records
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        out.push_str(&format!("  \"schema\": {BENCH_SCHEMA},\n"));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            let per_edge = match r.ns_per_edge {
                Some(v) => json_f64(v),
                None => "null".to_string(),
            };
            let samples = r.samples_ns.iter().map(|s| json_f64(*s)).collect::<Vec<_>>().join(", ");
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_ns\": {}, \"ns_per_edge\": {}, \
                 \"threads\": {}, \"iters\": {}, \"ci_lo_ns\": {}, \"ci_hi_ns\": {}, \
                 \"samples_ns\": [{}]}}{}\n",
                json_escape(&r.name),
                json_f64(r.mean_ns),
                per_edge,
                r.threads,
                r.iters,
                json_f64(r.ci_lo_ns),
                json_f64(r.ci_hi_ns),
                samples,
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write to `path` (e.g. `BENCH_decode.json`).
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

// ---------------------------------------------------------------------
// Statistical regression gate against tracked baselines
// ---------------------------------------------------------------------

/// One record parsed back from a tracked `BENCH_*.json`. Schema-1
/// files and placeholder baselines carry no CI bounds, so those fields
/// are `None` and the gate treats the record as ungateable.
#[derive(Clone, Debug)]
pub struct BaselineRecord {
    pub name: String,
    pub mean_ns: f64,
    pub ci_lo_ns: Option<f64>,
    pub ci_hi_ns: Option<f64>,
}

/// Parse a `BENCH_*.json` report into baseline records. Tolerant by
/// design: records missing a name are skipped, missing numeric fields
/// become NaN/None, and an empty `results` array (the tracked
/// placeholders) parses to an empty vector. Returns `None` only when
/// the document is not JSON or has no `results` array.
pub fn parse_baseline(text: &str) -> Option<Vec<BaselineRecord>> {
    let doc = Json::parse(text).ok()?;
    let results = doc.get("results")?.as_arr()?;
    let mut out = Vec::new();
    for r in results {
        let Some(name) = r.get("name").and_then(Json::as_str) else { continue };
        out.push(BaselineRecord {
            name: name.to_string(),
            mean_ns: r.get("mean_ns").and_then(Json::as_f64).unwrap_or(f64::NAN),
            ci_lo_ns: r.get("ci_lo_ns").and_then(Json::as_f64),
            ci_hi_ns: r.get("ci_hi_ns").and_then(Json::as_f64),
        });
    }
    Some(out)
}

/// Read a tracked baseline file; `None` when it is missing or not a
/// bench report (the gate then has nothing to compare against).
pub fn read_baseline(path: &std::path::Path) -> Option<Vec<BaselineRecord>> {
    parse_baseline(&std::fs::read_to_string(path).ok()?)
}

/// Default multiplicative slack on top of CI separation: machines
/// differ, so the gate fires only when the new interval sits wholly
/// above the baseline interval *times* this margin.
pub const BENCH_SLACK: f64 = 0.10;

/// The statistical regression gate. A record fails only when both
/// sides carry finite intervals and they separate on the slow side:
/// `new.ci_lo > base.ci_hi * (1 + slack)`. Everything else — records
/// missing from the baseline, placeholder baselines, schema-1
/// baselines without bounds, sample-less records — passes, so fresh
/// benches and baseline upgrades never wedge CI. Returns one message
/// per failing record; empty means the gate passes.
pub fn compare_against_baseline(
    current: &[JsonRecord],
    baseline: &[BaselineRecord],
    slack: f64,
) -> Vec<String> {
    let mut failures = Vec::new();
    for rec in current {
        let Some(base) = baseline.iter().find(|b| b.name == rec.name) else { continue };
        let (Some(b_lo), Some(b_hi)) = (base.ci_lo_ns, base.ci_hi_ns) else { continue };
        if !(rec.ci_lo_ns.is_finite() && rec.ci_hi_ns.is_finite() && b_hi.is_finite()) {
            continue;
        }
        if rec.ci_lo_ns > b_hi * (1.0 + slack) {
            failures.push(format!(
                "{}: regression — new mean CI [{:.0}, {:.0}] ns is disjoint above baseline CI \
                 [{:.0}, {:.0}] ns even with {:.0}% slack",
                rec.name,
                rec.ci_lo_ns,
                rec.ci_hi_ns,
                b_lo,
                b_hi,
                slack * 100.0
            ));
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0u64;
        let r = bench("noop", 1, Duration::from_millis(1), 5, || {
            count += 1;
        });
        assert!(r.iters >= 3);
        assert_eq!(count, r.iters + 1); // + warmup
        assert!(r.min <= r.mean);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn bench_args_parse() {
        let a = BenchArgs { args: vec!["--p".into(), "0.2".into(), "--quick".into()] };
        assert_eq!(a.f64_or("--p", 0.0), 0.2);
        assert!(a.quick());
        assert_eq!(a.usize_or("--runs", 50), 50);
    }

    #[test]
    fn usize_list_parsing() {
        let a = BenchArgs { args: vec!["--workers".into(), "2, 4,8".into()] };
        assert_eq!(a.usize_list_or("--workers", &[1]), vec![2, 4, 8]);
        assert_eq!(a.usize_list_or("--missing", &[3, 5]), vec![3, 5]);
        let bad = BenchArgs { args: vec!["--workers".into(), "2,x".into()] };
        assert_eq!(bad.usize_list_or("--workers", &[1]), vec![1]);
    }

    #[test]
    fn f64_hex_bits_round_trip() {
        for x in [0.0, -0.0, 1.5, -3.25e-30, f64::INFINITY, f64::NEG_INFINITY, f64::MIN_POSITIVE] {
            let s = f64_to_hex_bits(x);
            assert_eq!(s.len(), 16);
            assert_eq!(f64_from_hex_bits(&s).unwrap().to_bits(), x.to_bits(), "{x}");
        }
        // NaN payload preserved bit-for-bit
        let nan = f64::from_bits(0x7ff8_0000_0000_1234);
        assert_eq!(f64_from_hex_bits(&f64_to_hex_bits(nan)).unwrap().to_bits(), nan.to_bits());
        assert!(f64_from_hex_bits("xyz").is_none());
        assert!(f64_from_hex_bits("0").is_none());
    }

    #[test]
    fn json_f64_display_round_trips_and_guards() {
        for x in [0.1, -7.25, 1e300, 4.9e-324] {
            let s = json_f64_display(x);
            assert_eq!(s.parse::<f64>().unwrap().to_bits(), x.to_bits(), "{x}");
        }
        assert_eq!(json_f64_display(f64::NAN), "null");
        assert_eq!(json_f64_display(f64::INFINITY), "null");
    }

    #[test]
    fn json_report_round_trip() {
        let mut rep = JsonReport::new("bench_decode_perf");
        rep.push(JsonRecord {
            name: "graph-decode \"n=32768\"".into(),
            mean_ns: 1234.5678,
            ns_per_edge: Some(0.0125),
            threads: 8,
            iters: 100,
            ci_lo_ns: 1200.0,
            ci_hi_ns: 1260.25,
            samples_ns: vec![1190.0, 1234.0, 1280.0],
        });
        rep.push(JsonRecord {
            name: "lsqr".into(),
            mean_ns: 9.0,
            ns_per_edge: None,
            threads: 1,
            iters: 3,
            ci_lo_ns: f64::NAN,
            ci_hi_ns: f64::NAN,
            samples_ns: Vec::new(),
        });
        let s = rep.render();
        assert!(s.contains("\"bench\": \"bench_decode_perf\""));
        assert!(s.contains("\\\"n=32768\\\"")); // quotes escaped
        assert!(s.contains("\"threads\": 8"));
        assert!(s.contains("\"ns_per_edge\": null"));
        assert!(s.contains("\"schema\": 2"));
        assert!(s.contains("\"ci_lo_ns\": 1200.000"));
        assert!(s.contains("\"ci_hi_ns\": null")); // NaN interval -> null
        assert!(s.contains("\"samples_ns\": [1190.000, 1234.000, 1280.000]"));
        assert!(s.contains("\"samples_ns\": []"));
        // exactly one comma between the two records
        assert_eq!(s.matches("},\n").count(), 1);
        // writes to disk
        let dir = std::env::temp_dir().join("gcod_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        rep.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), s);
        // and parses back as a baseline, CI bounds intact
        let parsed = parse_baseline(&s).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].ci_hi_ns, Some(1260.25));
        assert_eq!(parsed[1].ci_lo_ns, None); // null round-trips to None
    }

    #[test]
    fn bootstrap_ci_is_deterministic_and_brackets_the_mean() {
        let samples: Vec<f64> =
            (0..200).map(|i| 1.0 + 0.01 * ((i * 37 % 100) as f64 / 100.0)).collect();
        let a = bootstrap_ci_mean(&samples, BOOT_RESAMPLES, BOOT_ALPHA, BOOT_SEED);
        let b = bootstrap_ci_mean(&samples, BOOT_RESAMPLES, BOOT_ALPHA, BOOT_SEED);
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1.to_bits(), b.1.to_bits());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!(a.0 <= mean && mean <= a.1, "CI {a:?} does not bracket mean {mean}");
        assert!(a.0 < a.1);
        // degenerate inputs collapse instead of panicking
        assert!(bootstrap_ci_mean(&[], 100, 0.05, 7).0.is_nan());
        assert_eq!(bootstrap_ci_mean(&[2.5], 100, 0.05, 7), (2.5, 2.5));
        let c = bootstrap_ci_mean(&[3.0; 50], 100, 0.05, 7);
        assert_eq!(c, (3.0, 3.0)); // constant samples -> point interval
    }

    #[test]
    fn stride_cap_keeps_order_and_bounds() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(stride_cap(&xs, 20), xs); // under the cap: unchanged
        let capped = stride_cap(&xs, 4);
        assert!(capped.len() <= 4);
        assert_eq!(capped[0], 0.0); // keeps the first element
        assert!(capped.windows(2).all(|w| w[0] < w[1])); // order preserved
    }

    #[test]
    fn baseline_gate_fails_only_on_separated_intervals() {
        let rec = |name: &str, lo: f64, hi: f64| JsonRecord {
            name: name.into(),
            mean_ns: (lo + hi) / 2.0,
            ns_per_edge: None,
            threads: 1,
            iters: 10,
            ci_lo_ns: lo,
            ci_hi_ns: hi,
            samples_ns: Vec::new(),
        };
        let base = |name: &str, lo: f64, hi: f64| BaselineRecord {
            name: name.into(),
            mean_ns: (lo + hi) / 2.0,
            ci_lo_ns: Some(lo),
            ci_hi_ns: Some(hi),
        };
        let baseline = vec![base("arm-slow", 100.0, 120.0), base("arm-ok", 100.0, 120.0)];
        // clear separation fails; overlap passes; missing-from-baseline passes
        let current = vec![
            rec("arm-slow", 200.0, 220.0),
            rec("arm-ok", 110.0, 180.0),
            rec("arm-new", 9999.0, 9999.5),
        ];
        let fails = compare_against_baseline(&current, &baseline, BENCH_SLACK);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("arm-slow"), "{}", fails[0]);
        // slack: lo=131 vs hi=120 * 1.10 = 132 is NOT a failure...
        let near = vec![rec("arm-slow", 131.0, 140.0)];
        assert!(compare_against_baseline(&near, &baseline, BENCH_SLACK).is_empty());
        // ...and a NaN interval (sample-less record) never gates
        let nan = vec![rec("arm-slow", f64::NAN, f64::NAN)];
        assert!(compare_against_baseline(&nan, &baseline, BENCH_SLACK).is_empty());
        // placeholder / schema-1 baselines (no CI bounds) never gate
        let plain = vec![BaselineRecord {
            name: "arm-slow".into(),
            mean_ns: 1.0,
            ci_lo_ns: None,
            ci_hi_ns: None,
        }];
        assert!(compare_against_baseline(&current, &plain, BENCH_SLACK).is_empty());
        assert!(compare_against_baseline(&current, &[], BENCH_SLACK).is_empty());
    }

    #[test]
    fn baseline_parser_tolerates_legacy_and_placeholder_files() {
        let mut rep = JsonReport::new("bench_x");
        rep.push(record_from_samples("k1", &[1.0e-6, 1.1e-6, 0.9e-6, 1.05e-6], Some(100), 2));
        let text = rep.render();
        let parsed = parse_baseline(&text).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].name, "k1");
        let (lo, hi) = (parsed[0].ci_lo_ns.unwrap(), parsed[0].ci_hi_ns.unwrap());
        assert!(lo <= parsed[0].mean_ns + 1e-6 && parsed[0].mean_ns <= hi + 1e-6);
        // schema-1 records parse without CI bounds
        let legacy = r#"{"bench": "x", "schema": 1, "results": [
            {"name": "old", "mean_ns": 5.0, "ns_per_edge": null, "threads": 1, "iters": 3}]}"#;
        let old = parse_baseline(legacy).unwrap();
        assert_eq!(old.len(), 1);
        assert_eq!(old[0].ci_lo_ns, None);
        // placeholder baselines parse to an empty (never-failing) set
        let placeholder = r#"{"bench": "x", "schema": 2, "note": "regen me", "results": []}"#;
        assert!(parse_baseline(placeholder).unwrap().is_empty());
        // non-reports are rejected, not misread
        assert!(parse_baseline("not json").is_none());
        assert!(parse_baseline("{\"schema\": 2}").is_none());
    }
}
