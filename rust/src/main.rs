//! `gcod` — launcher for the gradient-coding reproduction.
//!
//! Subcommands map to the paper's experiments; the benches under
//! rust/benches/ drive the same library APIs with full sweeps.

use gcod::cli::{flag, switch, App, CommandSpec};
use gcod::codes::zoo::{self, DecoderSpec, SchemeSpec};
use gcod::coordinator::{Cluster, ClusterConfig, ComputeBackend, StragglerInjection};
use gcod::dispatch::{
    fetch_job, query_status, submit_job, submit_job_nowait, worker_loop, ChaosProfile,
    ChaosTransport, DispatchConfig, Dispatcher, HealthConfig, JobSpec, LocalProcess,
    ServeConfig, StragglerSimCfg, WorkerOpts,
};
use gcod::error::{Error, Result};
use gcod::gd::{analysis, SimulatedGcod, StepSize};
use gcod::metrics::{sci, Table};
use gcod::obs::{self, LogFormat, Obs};
use gcod::prng::Rng;
use gcod::straggler::BernoulliStragglers;
use gcod::sweep::{self, shard};
use std::path::Path;
use std::time::Duration;

fn app() -> App {
    App {
        name: "gcod",
        about: "Approximate Gradient Coding with Optimal Decoding (Glasgow & Wootters 2021)",
        commands: vec![
            CommandSpec {
                name: "info",
                help: "artifact inventory + assignment-scheme statistics",
                flags: vec![
                    flag(
                        "scheme",
                        "scheme spec (e.g. graph-rr:16,3 | lps:5,13)",
                        Some("graph-rr:16,3"),
                    ),
                    flag("seed", "rng seed", Some("0")),
                    flag("artifacts", "artifacts dir", Some("artifacts")),
                    switch("spectral", "estimate the spectral gap (slower)"),
                ],
            },
            CommandSpec {
                name: "decode-error",
                help: "Monte-Carlo decoding error (Figure 3 point)",
                flags: vec![
                    flag("scheme", "scheme spec", Some("graph-rr:16,3")),
                    flag("decoder", "optimal|optimal-lsqr|fixed|ignore", Some("optimal")),
                    flag("p", "straggler probability", Some("0.2")),
                    flag("runs", "Monte-Carlo draws", Some("200")),
                    flag("seed", "rng seed", Some("0")),
                ],
            },
            CommandSpec {
                name: "simulate",
                help: "simulated coded GD on least squares (Figure 5 point)",
                flags: vec![
                    flag("scheme", "scheme spec", Some("graph-rr:16,3")),
                    flag("decoder", "optimal|fixed|ignore", Some("optimal")),
                    flag("p", "straggler probability", Some("0.2")),
                    flag("iters", "iterations", Some("50")),
                    flag("n-points", "data points N", Some("1024")),
                    flag("dim", "feature dim k", Some("64")),
                    flag("sigma", "observation noise", Some("1.0")),
                    flag("step-c", "grid index c for the step size", Some("9")),
                    flag("seed", "rng seed", Some("0")),
                ],
            },
            CommandSpec {
                name: "train",
                help: "distributed coded GD with worker threads (Figure 4 point)",
                flags: vec![
                    flag("scheme", "graph scheme spec", Some("graph-rr:16,3")),
                    flag("p", "injected straggler probability", Some("0.2")),
                    flag("iters", "iterations", Some("50")),
                    flag("n-points", "data points N", Some("6000")),
                    flag("dim", "feature dim k", Some("2000")),
                    flag("gamma", "step size", Some("2e-5")),
                    flag("backend", "pjrt|native", Some("pjrt")),
                    flag("artifacts", "artifacts dir", Some("artifacts")),
                    flag("seed", "rng seed", Some("0")),
                ],
            },
            CommandSpec {
                name: "adversarial",
                help: "adversarial decoding error vs theory (Cor. V.2/V.3)",
                flags: vec![
                    flag("scheme", "scheme spec", Some("graph-rr:16,3")),
                    flag("p", "straggler fraction", Some("0.2")),
                    flag("seed", "rng seed", Some("0")),
                ],
            },
            CommandSpec {
                name: "sweep-shard",
                help: "run one shard of a Monte-Carlo sweep, write a JSON manifest",
                flags: vec![
                    flag(
                        "sweep",
                        "sweep kernel: decode-error|gd-final|attack|adv-gd (open registry)",
                        Some("decode-error"),
                    ),
                    flag("scheme", "scheme spec", Some("graph-rr:16,3")),
                    flag("decoder", "optimal|optimal-lsqr|fixed|ignore", Some("optimal")),
                    flag("p", "straggler probability", Some("0.2")),
                    flag("trials", "total trials N across all shards", Some("1000")),
                    flag("seed", "sweep seed (shared by all shards)", Some("0")),
                    flag("chunk", "engine chunk size >= 1 (determinism contract)", Some("32")),
                    flag(
                        "threads",
                        "worker threads (0 = all cores; attack sweeps run serially)",
                        Some("0"),
                    ),
                    flag("shard", "shard spec i/k (contiguous split of [0,N))", Some("0/1")),
                    flag("range", "explicit trial range lo..hi (overrides --shard)", None),
                    flag("out", "manifest path (default sweep_<kind>_shard_<i>of<k>.json)", None),
                    switch(
                        "stats-only",
                        "omit the per-trial vector (smaller manifest, Chan-merge contract)",
                    ),
                ],
            },
            CommandSpec {
                name: "sweep-launch",
                help: "elastic fault-tolerant sweep across a pool of local worker processes",
                flags: vec![
                    flag(
                        "sweep",
                        "sweep kernel: decode-error|gd-final|attack|adv-gd (open registry)",
                        Some("decode-error"),
                    ),
                    flag("scheme", "scheme spec", Some("graph-rr:16,3")),
                    flag("decoder", "optimal|optimal-lsqr|fixed|ignore", Some("optimal")),
                    flag("p", "straggler probability", Some("0.2")),
                    flag("trials", "total trials N", Some("1000")),
                    flag("seed", "sweep seed", Some("0")),
                    flag("chunk", "engine chunk size >= 1 (determinism contract)", Some("32")),
                    flag("workers", "local worker processes", Some("4")),
                    flag(
                        "grain",
                        "initial lease size in trials (0 = auto, chunk-aligned)",
                        Some("0"),
                    ),
                    switch(
                        "adaptive-grain",
                        "shrink lease sizes as the queue drains (tail latency; bit-neutral)",
                    ),
                    flag("min-grain", "adaptive carve floor in trials (0 = one chunk)", Some("0")),
                    flag("threads", "engine threads per worker", Some("1")),
                    flag("lease-timeout-ms", "presume a lease lost after this long", Some("30000")),
                    flag(
                        "lease-timeout-per-trial-ms",
                        "per-trial addition to the lease deadline (scales with range length)",
                        Some("5"),
                    ),
                    flag("max-retries", "re-enqueues per range before failing", Some("3")),
                    flag("poll-ms", "dispatcher poll interval", Some("10")),
                    flag("out", "merged result path", Some("sweep_launched.json")),
                    flag(
                        "journal",
                        "checkpoint journal path: completed leases persist for --resume",
                        None,
                    ),
                    flag(
                        "resume",
                        "resume an interrupted launch from its journal (implies --journal)",
                        None,
                    ),
                    switch("stats-only", "stats-only manifests (relaxed Chan-merge contract)"),
                    switch("no-speculate", "disable speculative re-execution of slow ranges"),
                    flag(
                        "audit-fraction",
                        "fraction of leases re-executed on another worker and byte-compared",
                        Some("0"),
                    ),
                    flag(
                        "quarantine-after",
                        "audit condemnations before a worker is quarantined as byzantine",
                        Some("2"),
                    ),
                    flag(
                        "quarantine-after-failures",
                        "consecutive crash/timeouts before quarantine (0 = never)",
                        Some("0"),
                    ),
                    flag(
                        "backoff-base-ms",
                        "base respawn backoff after a worker failure (0 = none)",
                        Some("100"),
                    ),
                    flag(
                        "chaos-seed",
                        "deterministic chaos harness seed (replays the same fault plan)",
                        Some("0"),
                    ),
                    flag(
                        "chaos-profile",
                        "chaos preset none|kills|flaky|byzantine or k=v list \
                         (kill=0.1,delay=0.2,byz-worker=1,...)",
                        Some("none"),
                    ),
                    flag("kill-worker", "chaos preset: kill this worker id mid-shard", None),
                    flag(
                        "kill-after-ms",
                        "chaos preset: kill this long after job start",
                        Some("50"),
                    ),
                    flag("hang-worker", "chaos preset: this worker id stalls its next job", None),
                    flag("hang-ms", "chaos preset: stall duration (ms)", Some("120000")),
                    flag("sim-stragglers", "simulate Bernoulli(p) straggling workers", None),
                    flag("sim-delay-ms", "simulated straggler delay (ms)", Some("200")),
                    flag(
                        "log-format",
                        "stream structured scheduling events to stderr: text|json",
                        None,
                    ),
                    flag(
                        "trace-out",
                        "write a JSONL event trace here (input for `gcod report`)",
                        None,
                    ),
                ],
            },
            CommandSpec {
                name: "serve",
                help: "persistent TCP job coordinator: workers register, clients submit sweeps",
                flags: vec![
                    flag(
                        "bind",
                        "listen address host:port (port 0 = ephemeral)",
                        Some("127.0.0.1:7070"),
                    ),
                    flag(
                        "min-workers",
                        "hold queued jobs until this many workers are registered",
                        Some("1"),
                    ),
                    flag("poll-ms", "event-loop / dispatcher poll interval", Some("10")),
                    switch("once", "exit after the first job finishes (CI smokes)"),
                    flag(
                        "journal-dir",
                        "checkpoint each job to <dir>/job_<id>_<fp>.journal (resume on resubmit)",
                        None,
                    ),
                    flag(
                        "state-dir",
                        "durable coordinator state: specs, job states, manifests and per-job \
                         journals survive a crash/restart of the same dir",
                        None,
                    ),
                    switch(
                        "drain",
                        "work off the recovered backlog, then exit 0 once the queue is empty",
                    ),
                    flag(
                        "peer-silence-timeout-ms",
                        "presume a registered worker dead after this much mid-job silence",
                        Some("10000"),
                    ),
                    flag(
                        "log-format",
                        "stream structured scheduling events to stderr: text|json",
                        None,
                    ),
                    flag(
                        "trace-out",
                        "write a JSONL event trace here (input for `gcod report`)",
                        None,
                    ),
                ],
            },
            CommandSpec {
                name: "worker",
                help: "serve sweep leases to a gcod serve coordinator over TCP",
                flags: vec![
                    flag("connect", "coordinator address host:port", Some("127.0.0.1:7070")),
                    flag("class", "capability class to register with (empty = generic)", Some("")),
                    flag("threads", "engine threads offered per lease", Some("1")),
                    flag(
                        "connect-retries",
                        "connection attempts before giving up (the server may still be \
                         starting); also bounds each reconnect round after a lost session",
                        Some("50"),
                    ),
                    flag(
                        "retry-ms",
                        "pause between connection attempts (reconnects double it, capped at 5s)",
                        Some("100"),
                    ),
                    flag(
                        "log-format",
                        "stream structured scheduling events to stderr: text|json",
                        None,
                    ),
                    flag(
                        "trace-out",
                        "write a JSONL event trace here (input for `gcod report`)",
                        None,
                    ),
                ],
            },
            CommandSpec {
                name: "submit",
                help: "enqueue a sweep on a gcod serve coordinator and stream the merged result",
                flags: vec![
                    flag("connect", "coordinator address host:port", Some("127.0.0.1:7070")),
                    flag(
                        "sweep",
                        "sweep kernel: decode-error|gd-final|attack|adv-gd (open registry)",
                        Some("decode-error"),
                    ),
                    flag("scheme", "scheme spec", Some("graph-rr:16,3")),
                    flag("decoder", "optimal|optimal-lsqr|fixed|ignore", Some("optimal")),
                    flag("p", "straggler probability", Some("0.2")),
                    flag("trials", "total trials N", Some("1000")),
                    flag("seed", "sweep seed", Some("0")),
                    flag("chunk", "engine chunk size >= 1 (determinism contract)", Some("32")),
                    flag("class", "run only on workers of this capability class", Some("")),
                    flag(
                        "grain",
                        "initial lease size in trials (0 = auto, chunk-aligned)",
                        Some("0"),
                    ),
                    switch(
                        "adaptive-grain",
                        "shrink lease sizes as the queue drains (tail latency; bit-neutral)",
                    ),
                    flag("min-grain", "adaptive carve floor in trials (0 = one chunk)", Some("0")),
                    flag("threads", "engine threads per worker lease", Some("1")),
                    flag("lease-timeout-ms", "presume a lease lost after this long", Some("30000")),
                    flag(
                        "lease-timeout-per-trial-ms",
                        "per-trial addition to the lease deadline (scales with range length)",
                        Some("5"),
                    ),
                    flag("max-retries", "re-enqueues per range before failing", Some("3")),
                    switch("stats-only", "stats-only manifests (relaxed Chan-merge contract)"),
                    flag(
                        "audit-fraction",
                        "fraction of leases re-executed on another worker and byte-compared",
                        Some("0"),
                    ),
                    flag(
                        "chaos-seed",
                        "deterministic chaos harness seed (replays the same fault plan)",
                        Some("0"),
                    ),
                    flag(
                        "chaos-profile",
                        "chaos preset none|kills|flaky|byzantine or k=v list \
                         (kill=0.1,delay=0.2,byz-worker=1,...)",
                        Some("none"),
                    ),
                    flag("kill-worker", "chaos preset: kill this worker slot mid-lease", None),
                    flag(
                        "kill-after-ms",
                        "chaos preset: kill this long after job start",
                        Some("50"),
                    ),
                    flag("out", "merged result path", Some("sweep_submitted.json")),
                    flag(
                        "timeout-s",
                        "give up waiting for the result after this long",
                        Some("600"),
                    ),
                    switch("no-wait", "print the accepted job id and exit without waiting"),
                    flag(
                        "idempotency-key",
                        "client-chosen dedup token: resubmitting the same key returns the \
                         original job instead of re-executing",
                        None,
                    ),
                ],
            },
            CommandSpec {
                name: "fetch",
                help: "(re)attach to a submitted job and stream its merged result",
                flags: vec![
                    flag("connect", "coordinator address host:port", Some("127.0.0.1:7070")),
                    flag("job", "job id (printed by submit / submit --no-wait)", None),
                    flag("out", "merged result path", Some("sweep_fetched.json")),
                    flag(
                        "timeout-s",
                        "give up waiting for the result after this long",
                        Some("600"),
                    ),
                ],
            },
            CommandSpec {
                name: "status",
                help: "registry/queue/metrics snapshot from a gcod serve coordinator",
                flags: vec![
                    flag("connect", "coordinator address host:port", Some("127.0.0.1:7070")),
                    flag("timeout-s", "reply deadline", Some("10")),
                ],
            },
            CommandSpec {
                name: "sweep-merge",
                help: "validate + merge shard manifests into the canonical sweep result",
                flags: vec![
                    flag("input", "shard manifest path (repeatable)", None),
                    flag("inputs", "comma-separated shard manifest paths", None),
                    flag("out", "merged result path", Some("sweep_merged.json")),
                ],
            },
            CommandSpec {
                name: "report",
                help: "render a per-job lease timeline + worker health from a JSONL trace",
                flags: vec![flag(
                    "trace",
                    "JSONL event trace path (written by --trace-out)",
                    Some("trace.jsonl"),
                )],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let inv = match app().parse(&argv) {
        Ok(inv) => inv,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };
    let result = match inv.command.as_str() {
        "info" => cmd_info(&inv),
        "decode-error" => cmd_decode_error(&inv),
        "simulate" => cmd_simulate(&inv),
        "train" => cmd_train(&inv),
        "adversarial" => cmd_adversarial(&inv),
        "sweep-shard" => cmd_sweep_shard(&inv),
        "sweep-launch" => cmd_sweep_launch(&inv),
        "serve" => cmd_serve(&inv),
        "worker" => cmd_worker(&inv),
        "submit" => cmd_submit(&inv),
        "fetch" => cmd_fetch(&inv),
        "status" => cmd_status(&inv),
        "sweep-merge" => cmd_sweep_merge(&inv),
        "report" => cmd_report(&inv),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn build_scheme(inv: &gcod::cli::Invocation) -> Result<(zoo::BuiltScheme, Rng)> {
    let spec =
        SchemeSpec::parse(&inv.str_or("scheme", "graph-rr:16,3")).map_err(Error::msg)?;
    let mut rng = Rng::new(inv.u64_or("seed", 0));
    let scheme = zoo::build(&spec, &mut rng);
    Ok((scheme, rng))
}

fn cmd_info(inv: &gcod::cli::Invocation) -> Result<()> {
    let (scheme, mut rng) = build_scheme(inv)?;
    println!("scheme    : {}", scheme.name);
    println!("blocks n  : {}", scheme.n_blocks());
    println!("machines m: {}", scheme.n_machines());
    println!("replication d = {:.3}", scheme.replication());
    println!("load ell  : {} blocks/machine", scheme.a.max_col_nnz());
    if let Some(g) = &scheme.graph {
        println!("graph     : {} vertices, {} edges, connected={}", g.n, g.m(), g.is_connected());
        if inv.switch("spectral") {
            let l2 = gcod::graphs::spectral::lambda2(g, 4000, &mut rng);
            let d = g.is_regular().unwrap_or(0) as f64;
            println!("lambda_2  : {l2:.4}  (spectral gap lambda = {:.4}, Ramanujan bound {:.4})",
                     d - l2, 2.0 * (d - 1.0).sqrt());
        }
    }
    #[cfg(pjrt_runtime)]
    match gcod::runtime::Runtime::open(inv.str_or("artifacts", "artifacts")) {
        Ok(rt) => {
            println!("artifacts : {} loaded from manifest", rt.artifact_names().len());
            for n in rt.artifact_names() {
                println!("  - {n}");
            }
        }
        Err(e) => println!("artifacts : unavailable ({e})"),
    }
    #[cfg(not(pjrt_runtime))]
    println!("artifacts : pjrt feature not compiled in");
    Ok(())
}

fn cmd_decode_error(inv: &gcod::cli::Invocation) -> Result<()> {
    let (scheme, mut rng) = build_scheme(inv)?;
    let p = inv.f64_or("p", 0.2);
    let runs = inv.usize_or("runs", 200);
    let dspec = DecoderSpec::parse(&inv.str_or("decoder", "optimal")).map_err(Error::msg)?;
    let dec = zoo::make_decoder(&scheme, dspec, p);
    let mut strag = BernoulliStragglers::new(p, inv.u64_or("seed", 0) ^ 0xFEED);
    let stats = analysis::decoding_stats(
        dec.as_ref(), &mut strag, scheme.n_machines(), scheme.n_blocks(), runs, &mut rng);
    let d = scheme.replication();
    println!("scheme={} decoder={} p={p} runs={runs}", scheme.name, dec.name());
    println!("E|alpha_bar-1|^2/n = {}", sci(stats.mean_err_per_block));
    println!("|cov|_2            = {}", sci(stats.cov_norm));
    println!("normalization c    = {:.4}", stats.mean_alpha_scale);
    println!(
        "theory: optimal lower bound p^d/(1-p^d) = {}",
        sci(analysis::theory::optimal_lower_bound(p, d))
    );
    println!(
        "theory: fixed lower bound p/(d(1-p))    = {}",
        sci(analysis::theory::fixed_lower_bound(p, d))
    );
    Ok(())
}

fn cmd_simulate(inv: &gcod::cli::Invocation) -> Result<()> {
    let (scheme, mut rng) = build_scheme(inv)?;
    let p = inv.f64_or("p", 0.2);
    let n_points = inv.usize_or("n-points", 1024);
    let k = inv.usize_or("dim", 64);
    let sigma = inv.f64_or("sigma", 1.0);
    let iters = inv.usize_or("iters", 50);
    let dspec = DecoderSpec::parse(&inv.str_or("decoder", "optimal")).map_err(Error::msg)?;
    let data = gcod::data::LstsqData::generate(n_points, k, scheme.n_blocks(), sigma, &mut rng);
    let dec = zoo::make_decoder(&scheme, dspec, p);
    let mut strag = BernoulliStragglers::new(p, inv.u64_or("seed", 0) ^ 0xFACE);
    let rho = rng.permutation(scheme.n_blocks());
    let mut engine = SimulatedGcod {
        decoder: dec.as_ref(),
        stragglers: &mut strag,
        step: StepSize::simulated_grid(inv.usize_or("step-c", 9) as u32),
        rho: Some(rho),
        m: scheme.n_machines(),
        alpha_scale: 1.0,
    };
    let mut src = &data;
    let hist = engine.run(&mut src, &vec![0.0; k], iters);
    let mut table = Table::new(&["iter", "|theta-theta*|^2"]);
    for (i, e) in hist.progress.iter().enumerate().step_by((iters / 10).max(1)) {
        table.row(vec![i.to_string(), sci(*e)]);
    }
    table.row(vec![iters.to_string(), sci(hist.final_progress())]);
    table.print();
    Ok(())
}

fn cmd_train(inv: &gcod::cli::Invocation) -> Result<()> {
    let (scheme, mut rng) = build_scheme(inv)?;
    let graph = scheme
        .graph
        .as_ref()
        .ok_or_else(|| Error::msg("train needs a graph scheme"))?;
    let p = inv.f64_or("p", 0.2);
    let n_points = inv.usize_or("n-points", 6000);
    let k = inv.usize_or("dim", 2000);
    let data = gcod::data::LstsqData::generate(n_points, k, scheme.n_blocks(), 1.0, &mut rng);
    let backend = match inv.str_or("backend", "pjrt").as_str() {
        #[cfg(pjrt_runtime)]
        "pjrt" => {
            let art = format!("worker_grad_fig4_2x{}x{}", data.b, k);
            ComputeBackend::Pjrt {
                artifacts_dir: inv.str_or("artifacts", "artifacts"),
                artifact: art,
            }
        }
        other => {
            if other == "pjrt" {
                eprintln!("pjrt feature not compiled in; falling back to the native backend");
            }
            ComputeBackend::Native
        }
    };
    let cfg = ClusterConfig {
        wait_fraction: 1.0 - p,
        backend,
        injection: StragglerInjection::Random {
            p, delay: Duration::from_millis(200), seed: inv.u64_or("seed", 0) ^ 0xBEEF },
        step_size: inv.f64_or("gamma", 2e-5),
        iters: inv.usize_or("iters", 50),
        max_duration: None,
    };
    println!("spawning {} workers ({:?})...", scheme.n_machines(), cfg.backend);
    let mut cluster = Cluster::spawn(&scheme.a, &data, &cfg)?;
    cluster.wait_ready(Duration::from_secs(120))?;
    let dec = gcod::decode::OptimalGraphDecoder::new(graph);
    let report = cluster.run(&cfg, &dec, &vec![0.0; k], |t| data.dist_to_opt(t))?;
    cluster.shutdown();
    let mut table =
        Table::new(&["iter", "wall(ms)", "stragglers", "decode err^2", "|theta-theta*|^2"]);
    for s in report.iters.iter().step_by((cfg.iters / 10).max(1)) {
        table.row(vec![
            s.iter.to_string(),
            format!("{:.1}", s.wall.as_secs_f64() * 1e3),
            s.stragglers.to_string(),
            sci(s.decode_error_sq),
            sci(s.progress),
        ]);
    }
    table.print();
    println!(
        "total {:.2}s  final |theta-theta*|^2 = {}",
        report.total.as_secs_f64(),
        sci(report.final_progress)
    );
    Ok(())
}

/// Shared by `sweep-shard` and `sweep-launch`: the sweep identity from
/// the common flag set (extra parameters travel as `--set key=value`).
fn sweep_config_from(inv: &gcod::cli::Invocation) -> Result<shard::SweepConfig> {
    let mut params = inv.override_map().map_err(|e| Error::msg(e.to_string()))?;
    // `--set linalg=exact` is the default tier: strip it so the config
    // identity (and every manifest byte) matches the key being absent
    shard::canonicalize_linalg(&mut params);
    Ok(shard::SweepConfig {
        sweep: shard::SweepKind::parse(&inv.str_or("sweep", "decode-error"))?,
        scheme: inv.str_or("scheme", "graph-rr:16,3"),
        decoder: inv.str_or("decoder", "optimal"),
        p: inv.f64_or("p", 0.2),
        seed: inv.u64_or("seed", 0),
        trials: inv.usize_or("trials", 1000),
        chunk: inv.usize_or("chunk", sweep::DEFAULT_CHUNK),
        params,
    })
}

fn cmd_sweep_shard(inv: &gcod::cli::Invocation) -> Result<()> {
    // dispatch fault-injection/simulation hook: a worker process can be
    // made slow (straggler sim) or effectively hung (never heartbeats)
    // by its parent via this env var — see dispatch::transport
    if let Ok(ms) = std::env::var(gcod::dispatch::transport::DELAY_ENV) {
        // warn-and-ignore garbage: a stray exported value must not break
        // real runs
        match ms.parse::<u64>() {
            Ok(ms) => std::thread::sleep(Duration::from_millis(ms)),
            Err(e) => eprintln!(
                "ignoring unparseable {}='{ms}': {e}",
                gcod::dispatch::transport::DELAY_ENV
            ),
        }
    }
    let cfg = sweep_config_from(inv)?;
    let threads = match inv.usize_or("threads", 0) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        t => t,
    };
    let spec = shard::ShardSpec::parse(&inv.str_or("shard", "0/1"))?;
    let (label, default_out, res) = match inv.get("range") {
        Some(r) if !r.is_empty() => {
            let (lo, hi) = shard::parse_range(r)?;
            (
                format!("range {lo}..{hi}"),
                format!("sweep_{}_range_{lo}_{hi}.json", cfg.sweep.as_str()),
                shard::run_range(&cfg, threads, lo, hi)?,
            )
        }
        _ => (
            format!("shard {spec}"),
            format!("sweep_{}_shard_{}of{}.json", cfg.sweep.as_str(), spec.index, spec.count),
            shard::run_shard(&cfg, threads, spec)?,
        ),
    };
    let res = if inv.switch("stats-only") { res.into_stats_only() } else { res };
    let out = match inv.get("out") {
        Some(o) if !o.is_empty() => o.to_string(),
        _ => default_out,
    };
    res.write(Path::new(&out))?;
    println!(
        "{label} of sweep '{}' ({} {} p={} seed={}): trials [{}, {}) of {}{}",
        cfg.sweep.as_str(),
        cfg.scheme,
        cfg.decoder,
        cfg.p,
        cfg.seed,
        res.lo,
        res.hi,
        cfg.trials,
        if res.stats_only { " [stats-only]" } else { "" }
    );
    println!(
        "partial: count={} mean={} std={} min={} max={}",
        res.stats.count(),
        sci(res.stats.mean()),
        sci(res.stats.std()),
        sci(res.stats.min()),
        sci(res.stats.max())
    );
    println!("manifest written to {out}");
    Ok(())
}

fn cmd_sweep_launch(inv: &gcod::cli::Invocation) -> Result<()> {
    let cfg = sweep_config_from(inv)?;
    let workers = inv.usize_or("workers", 4).max(1);
    let out_dir = std::env::temp_dir().join(format!("gcod_launch_{}", std::process::id()));
    let audit_fraction = inv
        .str_or("audit-fraction", "0")
        .parse::<f64>()
        .map_err(|e| Error::msg(format!("bad --audit-fraction: {e}")))?;
    if !(0.0..=1.0).contains(&audit_fraction) {
        return Err(Error::msg(format!(
            "bad --audit-fraction: {audit_fraction} is not in [0, 1]"
        )));
    }
    let obs = build_obs(inv)?;
    let mut dcfg = DispatchConfig {
        grain: inv.usize_or("grain", 0),
        adaptive_grain: inv.switch("adaptive-grain"),
        min_grain: inv.usize_or("min-grain", 0),
        threads_per_worker: inv.usize_or("threads", 1),
        lease_timeout: Duration::from_millis(inv.u64_or("lease-timeout-ms", 30_000)),
        lease_timeout_per_trial: Duration::from_millis(
            inv.u64_or("lease-timeout-per-trial-ms", 5),
        ),
        max_retries: inv.usize_or("max-retries", 3),
        poll_interval: Duration::from_millis(inv.u64_or("poll-ms", 10)),
        speculate: !inv.switch("no-speculate"),
        stats_only: inv.switch("stats-only"),
        out_dir: out_dir.clone(),
        straggler_sim: None,
        audit_fraction,
        // derived from the sweep seed so a replayed launch audits the
        // same leases on the same sub-ranges
        audit_seed: cfg.seed ^ 0xA0D1_75EE,
        health: HealthConfig {
            quarantine_after: inv.usize_or("quarantine-after", 2),
            quarantine_after_failures: inv.usize_or("quarantine-after-failures", 0),
            backoff_base: Duration::from_millis(inv.u64_or("backoff-base-ms", 100)),
            ..HealthConfig::default()
        },
        journal: None,
        resume: false,
        stop: None,
        obs: obs.clone(),
        peer_silence_timeout: gcod::dispatch::tcp::DEAD_AFTER,
    };
    // --resume PATH replays (and keeps checkpointing to) an existing
    // journal; --journal PATH checkpoints a fresh launch
    match (inv.get("resume"), inv.get("journal")) {
        (Some(r), _) if !r.is_empty() => {
            dcfg.journal = Some(r.into());
            dcfg.resume = true;
        }
        (_, Some(j)) if !j.is_empty() => dcfg.journal = Some(j.into()),
        _ => {}
    }
    if let Some(p) = inv.get("sim-stragglers") {
        let p = p.parse::<f64>().map_err(|e| Error::msg(format!("bad --sim-stragglers: {e}")))?;
        dcfg.straggler_sim = Some(StragglerSimCfg {
            p,
            delay: Duration::from_millis(inv.u64_or("sim-delay-ms", 200)),
            seed: cfg.seed ^ 0x5157,
        });
    }
    let worker_id = |flag: &str| -> Result<Option<usize>> {
        match inv.get(flag) {
            None => Ok(None),
            Some(w) => {
                let w = w
                    .parse::<usize>()
                    .map_err(|e| Error::msg(format!("bad --{flag}: {e}")))?;
                if w >= workers {
                    return Err(Error::msg(format!(
                        "bad --{flag}: worker {w} out of range for {workers} workers"
                    )));
                }
                Ok(Some(w))
            }
        }
    };
    let chaos_profile = ChaosProfile::parse(&inv.str_or("chaos-profile", "none"))?;
    let chaos_seed = inv.u64_or("chaos-seed", 0);
    let exe = std::env::current_exe()?;
    let mut transport =
        ChaosTransport::new(LocalProcess::new(exe, workers), chaos_seed, chaos_profile);
    transport.set_obs(obs.clone());
    if let Some(w) = worker_id("hang-worker")? {
        transport.preset_delay(w, inv.u64_or("hang-ms", 120_000));
    }
    if let Some(w) = worker_id("kill-worker")? {
        transport.preset_kill(w, Duration::from_millis(inv.u64_or("kill-after-ms", 50)));
    }
    println!(
        "launching sweep '{}' ({} {} p={} seed={}, {} trials) on {workers} local worker(s)...",
        cfg.sweep.as_str(),
        cfg.scheme,
        cfg.decoder,
        cfg.p,
        cfg.seed,
        cfg.trials
    );
    let journal_path = dcfg.journal.clone();
    let result = Dispatcher::new(dcfg).run(&cfg, &mut transport);
    let _ = std::fs::remove_dir_all(&out_dir);
    if let (Err(e), Some(j)) = (&result, &journal_path) {
        // only when there is actually a checkpoint to resume, and the
        // failure isn't the journal machinery itself (resuming the
        // command that just failed to open its journal would loop)
        if j.is_file() && !format!("{e}").contains("journal") {
            eprintln!(
                "checkpoint journal kept at {} — re-run with `--resume {}` to recompute \
                 only the uncovered ranges",
                j.display(),
                j.display()
            );
        }
    }
    if transport.is_active() && !obs.enabled() {
        // the replayable fault sequence: re-running with the same
        // --chaos-seed and --chaos-profile reproduces it exactly (with
        // observability on, the same lines stream live as chaos-fault
        // events instead)
        for line in &transport.plan.log {
            println!("  [chaos] {line}");
        }
    }
    obs.flush();
    let outcome = result?;
    let out = inv.str_or("out", "sweep_launched.json");
    outcome.merged.write(Path::new(&out))?;
    println!("{}", outcome.report.summary());
    if !obs.enabled() {
        for line in &outcome.report.failure_log {
            println!("  [fault] {line}");
        }
    }
    println!(
        "result: mean={} std={} min={} max={}",
        sci(outcome.merged.stats.mean()),
        sci(outcome.merged.stats.std()),
        sci(outcome.merged.stats.min()),
        sci(outcome.merged.stats.max())
    );
    println!("merged result written to {out}");
    Ok(())
}

fn cmd_serve(inv: &gcod::cli::Invocation) -> Result<()> {
    let mut cfg = ServeConfig::new(inv.str_or("bind", "127.0.0.1:7070"));
    cfg.min_workers = inv.usize_or("min-workers", 1);
    cfg.poll = Duration::from_millis(inv.u64_or("poll-ms", 10));
    cfg.once = inv.switch("once");
    if let Some(d) = inv.get("journal-dir") {
        if !d.is_empty() {
            std::fs::create_dir_all(d)
                .map_err(|e| Error::msg(format!("create --journal-dir {d}: {e}")))?;
            cfg.journal_dir = Some(d.into());
        }
    }
    if let Some(d) = inv.get("state-dir") {
        if !d.is_empty() {
            std::fs::create_dir_all(d)
                .map_err(|e| Error::msg(format!("create --state-dir {d}: {e}")))?;
            cfg.state_dir = Some(d.into());
        }
    }
    cfg.drain_when_idle = inv.switch("drain");
    // SIGTERM means drain, not die: stop leasing, let in-flight leases
    // land or journal, say goodbye, persist, exit 0
    cfg.drain = gcod::dispatch::sys::install_sigterm_drain();
    cfg.peer_silence = Duration::from_millis(inv.u64_or("peer-silence-timeout-ms", 10_000));
    cfg.obs = build_obs(inv)?;
    gcod::dispatch::serve(&cfg)
}

/// Shared `--log-format`/`--trace-out` wiring: both flags absent means
/// observability stays a no-op handle (zero event allocation on the
/// dispatch path); either one turns the flight recorder on and attaches
/// the requested sinks.
fn build_obs(inv: &gcod::cli::Invocation) -> Result<Obs> {
    let log_format = inv.get("log-format").filter(|s| !s.is_empty());
    let trace_out = inv.get("trace-out").filter(|s| !s.is_empty());
    if log_format.is_none() && trace_out.is_none() {
        return Ok(Obs::default());
    }
    let mut obs = Obs::new();
    if let Some(f) = log_format {
        obs = obs.with_stderr(LogFormat::parse(f)?);
    }
    if let Some(p) = trace_out {
        obs = obs.with_trace_file(Path::new(p))?;
    }
    Ok(obs)
}

fn cmd_report(inv: &gcod::cli::Invocation) -> Result<()> {
    let trace = inv.str_or("trace", "trace.jsonl");
    print!("{}", obs::report::render(Path::new(&trace))?);
    Ok(())
}

fn cmd_worker(inv: &gcod::cli::Invocation) -> Result<()> {
    let mut opts =
        WorkerOpts::new(inv.str_or("connect", "127.0.0.1:7070"), std::env::current_exe()?);
    opts.class = inv.str_or("class", "");
    opts.threads = inv.usize_or("threads", 1).max(1);
    opts.connect_retries = inv.usize_or("connect-retries", 50);
    opts.retry_delay = Duration::from_millis(inv.u64_or("retry-ms", 100));
    let obs = build_obs(inv)?;
    opts.obs = obs.clone();
    println!(
        "gcod worker: serving coordinator {} (class '{}', {} thread(s))...",
        opts.coordinator, opts.class, opts.threads
    );
    let result = worker_loop(&opts);
    obs.flush();
    let completed = result?;
    println!("gcod worker: coordinator said goodbye after {completed} completed lease(s)");
    Ok(())
}

fn cmd_submit(inv: &gcod::cli::Invocation) -> Result<()> {
    let cfg = sweep_config_from(inv)?;
    let audit_fraction = inv
        .str_or("audit-fraction", "0")
        .parse::<f64>()
        .map_err(|e| Error::msg(format!("bad --audit-fraction: {e}")))?;
    if !(0.0..=1.0).contains(&audit_fraction) {
        return Err(Error::msg(format!(
            "bad --audit-fraction: {audit_fraction} is not in [0, 1]"
        )));
    }
    let mut spec = JobSpec::new(cfg);
    spec.class = inv.str_or("class", "");
    spec.grain = inv.usize_or("grain", 0);
    spec.adaptive_grain = inv.switch("adaptive-grain");
    spec.min_grain = inv.usize_or("min-grain", 0);
    spec.threads_per_worker = inv.usize_or("threads", 1);
    spec.lease_timeout_ms = inv.u64_or("lease-timeout-ms", 30_000);
    spec.lease_timeout_per_trial_ms = inv.u64_or("lease-timeout-per-trial-ms", 5);
    spec.max_retries = inv.usize_or("max-retries", 3);
    spec.stats_only = inv.switch("stats-only");
    spec.audit_fraction = audit_fraction;
    spec.chaos_seed = inv.u64_or("chaos-seed", 0);
    spec.chaos_profile = inv.str_or("chaos-profile", "none");
    // fail bad chaos specs client-side, before the job queues
    ChaosProfile::parse(&spec.chaos_profile)?;
    spec.kill_worker = match inv.get("kill-worker") {
        None => None,
        Some(w) => {
            Some(w.parse::<usize>().map_err(|e| Error::msg(format!("bad --kill-worker: {e}")))?)
        }
    };
    spec.kill_after_ms = inv.u64_or("kill-after-ms", 50);
    spec.idempotency_key = inv.str_or("idempotency-key", "");
    let addr = inv.str_or("connect", "127.0.0.1:7070");
    let timeout = Duration::from_secs(inv.u64_or("timeout-s", 600));
    println!(
        "submitting sweep '{}' ({} {} p={} seed={}, {} trials) to {addr}...",
        spec.config.sweep.as_str(),
        spec.config.scheme,
        spec.config.decoder,
        spec.config.p,
        spec.config.seed,
        spec.config.trials
    );
    if inv.switch("no-wait") {
        let id = submit_job_nowait(&addr, spec, timeout)?;
        println!("job {id} accepted by {addr}");
        return Ok(());
    }
    let outcome = submit_job(&addr, spec, timeout)?;
    // the manifest crossed a network: re-validate before trusting it
    let merged = shard::MergedSweep::parse(&outcome.manifest)?;
    let out = inv.str_or("out", "sweep_submitted.json");
    std::fs::write(&out, &outcome.manifest)
        .map_err(|e| Error::msg(format!("write {out}: {e}")))?;
    println!("job {} done: {}", outcome.job, outcome.summary);
    println!(
        "result: mean={} std={} min={} max={}",
        sci(merged.stats.mean()),
        sci(merged.stats.std()),
        sci(merged.stats.min()),
        sci(merged.stats.max())
    );
    println!("merged result written to {out}");
    Ok(())
}

fn cmd_fetch(inv: &gcod::cli::Invocation) -> Result<()> {
    let addr = inv.str_or("connect", "127.0.0.1:7070");
    let job = inv
        .get("job")
        .filter(|s| !s.is_empty())
        .ok_or_else(|| Error::msg("fetch needs --job <id>"))?
        .parse::<u64>()
        .map_err(|e| Error::msg(format!("bad --job: {e}")))?;
    let timeout = Duration::from_secs(inv.u64_or("timeout-s", 600));
    println!("fetching job {job} from {addr}...");
    let outcome = fetch_job(&addr, job, timeout)?;
    // the manifest crossed a network: re-validate before trusting it
    let merged = shard::MergedSweep::parse(&outcome.manifest)?;
    let out = inv.str_or("out", "sweep_fetched.json");
    std::fs::write(&out, &outcome.manifest)
        .map_err(|e| Error::msg(format!("write {out}: {e}")))?;
    println!("job {} done: {}", outcome.job, outcome.summary);
    println!(
        "result: mean={} std={} min={} max={}",
        sci(merged.stats.mean()),
        sci(merged.stats.std()),
        sci(merged.stats.min()),
        sci(merged.stats.max())
    );
    println!("merged result written to {out}");
    Ok(())
}

fn cmd_status(inv: &gcod::cli::Invocation) -> Result<()> {
    let addr = inv.str_or("connect", "127.0.0.1:7070");
    let timeout = Duration::from_secs(inv.u64_or("timeout-s", 10));
    print!("{}", query_status(&addr, timeout)?);
    Ok(())
}

fn cmd_sweep_merge(inv: &gcod::cli::Invocation) -> Result<()> {
    let mut paths: Vec<String> = inv.get_all("input").iter().map(|s| s.to_string()).collect();
    if let Some(list) = inv.get("inputs") {
        paths.extend(list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()));
    }
    if paths.is_empty() {
        return Err(Error::msg("sweep-merge needs at least one --input (or --inputs) manifest"));
    }
    let shards: Vec<shard::ShardResult> = paths
        .iter()
        .map(|p| shard::ShardResult::read(Path::new(p)))
        .collect::<Result<_>>()?;
    let merged = shard::merge(shards)?;
    let out = inv.str_or("out", "sweep_merged.json");
    merged.write(Path::new(&out))?;
    println!(
        "merged {} shard manifest(s): sweep '{}' ({} {} p={} seed={}), {} trials",
        paths.len(),
        merged.config.sweep.as_str(),
        merged.config.scheme,
        merged.config.decoder,
        merged.config.p,
        merged.config.seed,
        merged.config.trials
    );
    println!(
        "result: mean={} std={} min={} max={}",
        sci(merged.stats.mean()),
        sci(merged.stats.std()),
        sci(merged.stats.min()),
        sci(merged.stats.max())
    );
    println!("merged result written to {out}");
    Ok(())
}

fn cmd_adversarial(inv: &gcod::cli::Invocation) -> Result<()> {
    let (scheme, _rng) = build_scheme(inv)?;
    let p = inv.f64_or("p", 0.2);
    let budget = (p * scheme.n_machines() as f64).floor() as usize;
    let dec = zoo::make_decoder(&scheme, DecoderSpec::Optimal, p);
    let mask = if let Some(g) = &scheme.graph {
        gcod::straggler::graph_isolation_attack(g, budget)
    } else if let Some(frc) = &scheme.frc {
        gcod::straggler::frc_group_attack(frc, budget)
    } else {
        gcod::straggler::greedy_decode_attack(dec.as_ref(), &scheme.a, budget)
    };
    let err = dec.decode(&mask).error_sq() / scheme.n_blocks() as f64;
    println!("scheme={} budget={budget} machines", scheme.name);
    println!("adversarial |alpha*-1|^2/n = {}", sci(err));
    println!("graph lower bound p/2       = {}", sci(analysis::theory::graph_adversarial_lower(p)));
    if let Some(g) = &scheme.graph {
        let mut rng2 = Rng::new(99);
        let lambda = gcod::graphs::spectral::spectral_gap(g, 3000, &mut rng2);
        let d = scheme.replication();
        println!(
            "Cor V.2 upper bound         = {}",
            sci(analysis::theory::graph_adversarial_bound(p, d, lambda))
        );
    }
    Ok(())
}
