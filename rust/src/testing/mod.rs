//! Property-testing mini-framework (no proptest in the offline build).
//!
//! `check(name, cases, prop)` runs `prop` against `cases` independent
//! PRNG streams; on failure it reports the failing case seed so the
//! exact case can be replayed with `check_seed`. Generators are plain
//! functions over [`crate::prng::Rng`]. Shrinking is approximated by
//! re-running failing numeric-size parameters at smaller values where
//! the generator supports it (callers draw sizes via `Gen::size`).

use crate::prng::Rng;

/// Size-aware generation helper: properties draw their dimensions
/// through this so failures can be replayed at reduced size.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// multiplicative size cap in (0, 1]; 1.0 = full size
    pub size_factor: f64,
}

impl Gen<'_> {
    /// A size in [lo, hi], scaled down by the shrink factor.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + (((hi - lo) as f64) * self.size_factor).round() as usize;
        lo + self.rng.below(hi_eff - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn choice<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult {
    Ok,
    Failed { seed: u64, case: usize, msg: String },
}

/// Run `prop` on `cases` random cases; panics (test failure) with the
/// reproducing seed on the first violation. A property returns
/// `Err(msg)` (or panics) to signal failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = match std::env::var("GCOD_PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("GCOD_PROP_SEED must be a u64"),
        Err(_) => 0xC0DE_D00D,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        if let PropResult::Failed { seed, case, msg } = run_case(&mut prop, seed, case, 1.0) {
            // attempt shrink: re-run at reduced size factors with the same seed
            for &factor in &[0.25, 0.5] {
                if let PropResult::Failed { msg: small_msg, .. } =
                    run_case(&mut prop, seed, case, factor)
                {
                    panic!(
                        "property '{name}' failed (case {case}, seed {seed}, \
                         shrunk to {factor}x): {small_msg}"
                    );
                }
            }
            panic!("property '{name}' failed (case {case}, seed {seed}): {msg}");
        }
    }
}

/// Replay one exact case (debugging helper).
pub fn check_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let PropResult::Failed { msg, .. } = run_case(&mut prop, seed, 0, 1.0) {
        panic!("property '{name}' failed at seed {seed}: {msg}");
    }
}

fn run_case<F>(prop: &mut F, seed: u64, case: usize, size_factor: f64) -> PropResult
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let mut gen = Gen { rng: &mut rng, size_factor };
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut gen))) {
        Ok(Ok(())) => PropResult::Ok,
        Ok(Err(msg)) => PropResult::Failed { seed, case, msg },
        Err(p) => {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            PropResult::Failed { seed, case, msg: format!("panicked: {msg}") }
        }
    }
}

/// Assert helper producing property-friendly errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-15, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 5, |_| Err("nope".to_string()));
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panicking_property_is_caught() {
        check("panics", 3, |_| panic!("boom"));
    }

    #[test]
    fn sizes_respect_bounds() {
        check("sizes", 100, |g| {
            let s = g.size(3, 17);
            prop_assert!((3..=17).contains(&s), "s={s}");
            Ok(())
        });
    }
}
